"""Encoder-decoder transformer backbone (seamless-m4t-large-v2, audio).

The modality frontend (mel-spectrogram + conformer feature extractor) is a
STUB per the assignment carve-out: the model consumes precomputed frame
embeddings (B, frames, d_model). We implement the full transformer backbone:
bidirectional encoder, causal decoder with cross-attention, text unembedding.

Serving: ``prefill`` runs the encoder once, precomputes per-layer cross K/V
(static for the whole generation), and initializes the decoder self cache.
``decode_step`` is one decoder token.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (gqa_cross_forward, gqa_decode_step, gqa_forward,
                        gqa_prefill, init_gqa_params)
from .common import (ArchConfig, KeyGen, Params, dense_init, embed_init,
                     rms_norm, stack_layer_params, swiglu)


def _init_ffn(kg: KeyGen, cfg: ArchConfig, dtype) -> Dict:
    return {
        "w_gate": dense_init(kg(), (cfg.d_model, cfg.d_ff), dtype),
        "w_up": dense_init(kg(), (cfg.d_model, cfg.d_ff), dtype),
        "w_down": dense_init(kg(), (cfg.d_ff, cfg.d_model), dtype),
    }


def init_enc_layer(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {"attn": init_gqa_params(kg, cfg, dtype),
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype),
            **_init_ffn(kg, cfg, dtype)}


def init_dec_layer(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {"self_attn": init_gqa_params(kg, cfg, dtype),
            "self_norm": jnp.ones((cfg.d_model,), dtype),
            "cross_attn": init_gqa_params(kg, cfg, dtype),
            "cross_norm": jnp.ones((cfg.d_model,), dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype),
            **_init_ffn(kg, cfg, dtype)}


def init_params(rng: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    kg = KeyGen(rng)
    return {
        "embed": embed_init(kg(), (cfg.vocab, cfg.d_model), dtype),
        "enc_layers": stack_layer_params(
            functools.partial(init_enc_layer, cfg=cfg, dtype=dtype),
            cfg.enc_layers, kg),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "dec_layers": stack_layer_params(
            functools.partial(init_dec_layer, cfg=cfg, dtype=dtype),
            cfg.dec_layers, kg),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "unembed": dense_init(kg(), (cfg.d_model, cfg.vocab), dtype),
    }


def encode(params: Params, cfg: ArchConfig, frame_embeds: jnp.ndarray,
           remat: bool = True) -> jnp.ndarray:
    """Bidirectional encoder over stub frame embeddings (B, F, d)."""
    B, F, _ = frame_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def scan_fn(x, layer):
        x = x + gqa_forward(layer["attn"], cfg,
                            rms_norm(x, layer["attn_norm"], cfg.norm_eps),
                            positions, causal=False)
        x = x + swiglu(rms_norm(x, layer["mlp_norm"], cfg.norm_eps),
                       layer["w_gate"], layer["w_up"], layer["w_down"])
        return x, None

    if remat:
        scan_fn = jax.checkpoint(scan_fn)
    h, _ = jax.lax.scan(scan_fn, frame_embeds, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _dec_layer_fwd(layer: Dict, cfg: ArchConfig, x: jnp.ndarray,
                   enc_out: jnp.ndarray, positions: jnp.ndarray):
    x = x + gqa_forward(layer["self_attn"], cfg,
                        rms_norm(x, layer["self_norm"], cfg.norm_eps),
                        positions)
    x = x + gqa_cross_forward(layer["cross_attn"], cfg,
                              rms_norm(x, layer["cross_norm"], cfg.norm_eps),
                              enc_out)
    x = x + swiglu(rms_norm(x, layer["mlp_norm"], cfg.norm_eps),
                   layer["w_gate"], layer["w_up"], layer["w_down"])
    return x


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            embeds: jnp.ndarray, remat: bool = True) -> jnp.ndarray:
    """Training forward: embeds = frame embeddings (B,F,d); tokens =
    decoder text tokens (B,S). Returns decoder logits (B,S,vocab)."""
    enc_out = encode(params, cfg, embeds, remat)
    h = params["embed"][tokens]
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    body = functools.partial(_dec_layer_fwd, cfg=cfg, enc_out=enc_out,
                             positions=positions)
    if remat:
        body = jax.checkpoint(body)

    def scan_fn(x, layer):
        return body(layer, x=x), None

    h, _ = jax.lax.scan(scan_fn, h, params["dec_layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps) @ params["unembed"]


# ------------------------------------------------------------------ serving
def init_cache(cfg: ArchConfig, batch: int, max_len: int, n_frames: int,
               dtype=jnp.float32) -> Dict:
    Hkv, D = cfg.n_kv_heads, cfg.hd()
    L = cfg.dec_layers
    M = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((L, batch, M, Hkv, D), dtype),
        "v": jnp.zeros((L, batch, M, Hkv, D), dtype),
        "xk": jnp.zeros((L, batch, n_frames, Hkv, D), dtype),
        "xv": jnp.zeros((L, batch, n_frames, Hkv, D), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def prefill(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            cache: Dict, embeds: jnp.ndarray, remat: bool = True):
    """Encode frames + run decoder prompt; fill self + cross caches."""
    enc_out = encode(params, cfg, embeds, remat)
    Hkv, D = cfg.n_kv_heads, cfg.hd()
    B, F, _ = enc_out.shape
    h = params["embed"][tokens]
    S = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def scan_fn(x, layer_kv):
        layer, k, v = layer_kv
        attn_out, nk, nv = gqa_prefill(
            k, v, layer["self_attn"], cfg,
            rms_norm(x, layer["self_norm"], cfg.norm_eps), positions)
        x = x + attn_out
        xk = (enc_out @ layer["cross_attn"]["wk"]).reshape(B, F, Hkv, D)
        xv = (enc_out @ layer["cross_attn"]["wv"]).reshape(B, F, Hkv, D)
        x = x + gqa_cross_forward(layer["cross_attn"], cfg,
                                  rms_norm(x, layer["cross_norm"],
                                           cfg.norm_eps), enc_out)
        x = x + swiglu(rms_norm(x, layer["mlp_norm"], cfg.norm_eps),
                       layer["w_gate"], layer["w_up"], layer["w_down"])
        return x, (nk, nv, xk, xv)

    if remat:
        scan_fn = jax.checkpoint(scan_fn)
    h, (ks, vs, xks, xvs) = jax.lax.scan(
        scan_fn, h, (params["dec_layers"], cache["k"], cache["v"]))
    new_cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs,
                 "idx": jnp.asarray(S, jnp.int32)}
    logits = (rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
              @ params["unembed"])[:, 0]
    return logits, new_cache


def decode_step(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                cache: Dict):
    """One decoder token using self cache + precomputed cross K/V."""
    h = params["embed"][tokens]
    B = h.shape[0]
    idx = cache["idx"]
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd()

    def scan_fn(x, layer_kv):
        layer, k, v, xk, xv = layer_kv
        attn_out, nk, nv = gqa_decode_step(
            k, v, idx, layer["self_attn"], cfg,
            rms_norm(x, layer["self_norm"], cfg.norm_eps))
        x = x + attn_out
        # cross attention against cached xk/xv
        xn = rms_norm(x, layer["cross_norm"], cfg.norm_eps)
        q = (xn @ layer["cross_attn"]["wq"]).reshape(B, 1, H, D)
        from .attention import _grouped_attention
        out = _grouped_attention(q, xk, xv, jnp.zeros((), jnp.float32))
        x = x + out.reshape(B, 1, H * D) @ layer["cross_attn"]["wo"]
        x = x + swiglu(rms_norm(x, layer["mlp_norm"], cfg.norm_eps),
                       layer["w_gate"], layer["w_up"], layer["w_down"])
        return x, (nk, nv)

    h, (ks, vs) = jax.lax.scan(
        scan_fn, h, (params["dec_layers"], cache["k"], cache["v"],
                     cache["xk"], cache["xv"]))
    new_cache = {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                 "idx": idx + 1}
    logits = (rms_norm(h, params["final_norm"], cfg.norm_eps)
              @ params["unembed"])[:, 0]
    return logits, new_cache

"""RWKV6 "Finch" (arXiv:2404.05892) — attention-free, data-dependent decay.

Per layer: a time-mixing block (the WKV linear-attention recurrence with
per-channel dynamic decay w_t produced by a LoRA of the shifted input) and a
channel-mixing block (squared-ReLU FFN with token shift). Decode state is
O(1) in sequence length — (head, d_k, d_v) matrix per layer plus the last
token for the shifts — which is why rwkv6 runs `long_500k` natively.

WKV recurrence per head (d_k = d_v = head size):
  out_t = r_t . (S + u (*) k_t v_t^T)
  S     = diag(w_t) S + k_t v_t^T

Training/prefill uses a time ``lax.scan`` (the recurrence is inherently
sequential in w_t; the chunked form is a beyond-paper perf option tracked in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, Params, dense_init, embed_init, rms_norm

LORA_R = 32          # decay/mix LoRA rank
MIX_KEYS = ("r", "k", "v", "w", "g")


def head_size(cfg: ArchConfig) -> int:
    return cfg.hd()


def n_rwkv_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // head_size(cfg)


def init_time_mix(kg: KeyGen, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    H, K = n_rwkv_heads(cfg), head_size(cfg)
    p = {
        "mu_base": jax.random.uniform(kg(), (d,), jnp.float32).astype(dtype),
        "w0": jnp.zeros((d,), dtype),
        "w_lora_a": dense_init(kg(), (d, LORA_R * 2), dtype),
        "w_lora_b": dense_init(kg(), (LORA_R * 2, d), dtype, scale=0.01),
        "u": dense_init(kg(), (H, K), jnp.float32).astype(dtype),  # bonus
        "wr": dense_init(kg(), (d, d), dtype),
        "wk": dense_init(kg(), (d, d), dtype),
        "wv": dense_init(kg(), (d, d), dtype),
        "wg": dense_init(kg(), (d, d), dtype),
        "wo": dense_init(kg(), (d, d), dtype),
        "ln_scale": jnp.ones((d,), dtype),
    }
    for name in MIX_KEYS:
        p[f"mu_{name}"] = jax.random.uniform(kg(), (d,),
                                             jnp.float32).astype(dtype)
        p[f"mix_a_{name}"] = dense_init(kg(), (d, LORA_R), dtype)
        p[f"mix_b_{name}"] = dense_init(kg(), (LORA_R, d), dtype, scale=0.01)
    return p


def init_channel_mix(kg: KeyGen, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    return {
        "mu_k": jax.random.uniform(kg(), (d,), jnp.float32).astype(dtype),
        "mu_r": jax.random.uniform(kg(), (d,), jnp.float32).astype(dtype),
        "wk": dense_init(kg(), (d, cfg.d_ff), dtype),
        "wv": dense_init(kg(), (cfg.d_ff, d), dtype),
        "wr": dense_init(kg(), (d, d), dtype),
    }


def _ddlerp(p: Dict, name: str, x: jnp.ndarray,
            x_prev: jnp.ndarray) -> jnp.ndarray:
    """RWKV6 data-dependent lerp between x and the shifted x_prev."""
    dx = x_prev - x
    xx = x + dx * p["mu_base"]
    lora = jnp.tanh(xx @ p[f"mix_a_{name}"]) @ p[f"mix_b_{name}"]
    return x + dx * (p[f"mu_{name}"] + lora)


def _shift(x: jnp.ndarray, last: jnp.ndarray) -> jnp.ndarray:
    """Token shift: previous token's activation ((B,S,d), carry (B,d))."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def time_mix(p: Dict, cfg: ArchConfig, x: jnp.ndarray, last: jnp.ndarray,
             wkv_state: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d), last: (B,d) previous token, wkv_state: (B,H,K,K).
    Returns (out, new last, new wkv_state)."""
    B, S, d = x.shape
    H, K = n_rwkv_heads(cfg), head_size(cfg)
    xp = _shift(x, last)
    r = _ddlerp(p, "r", x, xp) @ p["wr"]
    k = _ddlerp(p, "k", x, xp) @ p["wk"]
    v = _ddlerp(p, "v", x, xp) @ p["wv"]
    g = _ddlerp(p, "g", x, xp) @ p["wg"]
    # dynamic decay: w_t = exp(-exp(w0 + lora_w)) in (0, 1), per channel
    wl = (jnp.tanh(_ddlerp(p, "w", x, xp) @ p["w_lora_a"][:, :LORA_R])
          @ p["w_lora_b"][:LORA_R])
    logw = -jnp.exp(jnp.clip(p["w0"] + wl, -10.0, 5.0))
    w = jnp.exp(logw)                                      # (B,S,d)

    rh = r.reshape(B, S, H, K)
    kh = k.reshape(B, S, H, K)
    vh = v.reshape(B, S, H, K)
    wh = w.reshape(B, S, H, K)

    def scan_fn(state, inp):
        rt, kt, vt, wt = inp                               # (B,H,K) each
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,K,K)
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + p["u"][..., None] * kv)
        new_state = wt[..., :, None] * state + kv
        return new_state, out

    inp = tuple(jnp.moveaxis(a, 1, 0) for a in (rh, kh, vh, wh))
    new_state, outs = jax.lax.scan(scan_fn, wkv_state, inp)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, d)
    out = rms_norm(out, p["ln_scale"], cfg.norm_eps)       # per-head GN approx
    out = out * jax.nn.silu(g)
    return out @ p["wo"], x[:, -1], new_state


def channel_mix(p: Dict, cfg: ArchConfig, x: jnp.ndarray, last: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xp = _shift(x, last)
    xk = x + (xp - x) * p["mu_k"]
    xr = x + (xp - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1]


def init_layer(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "tm": init_time_mix(kg, cfg, dtype),
        "cm": init_channel_mix(kg, cfg, dtype),
    }


def init_params(rng: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    kg = KeyGen(rng)
    from .common import stack_layer_params
    import functools
    return {
        "embed": embed_init(kg(), (cfg.vocab, cfg.d_model), dtype),
        "ln_in": jnp.ones((cfg.d_model,), dtype),
        "layers": stack_layer_params(
            functools.partial(init_layer, cfg=cfg, dtype=dtype),
            cfg.n_layers, kg),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "unembed": dense_init(kg(), (cfg.d_model, cfg.vocab), dtype),
    }


def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    """Recurrent state for all layers (the rwkv 'cache')."""
    H, K = n_rwkv_heads(cfg), head_size(cfg)
    L, d = cfg.n_layers, cfg.d_model
    return {
        "tm_last": jnp.zeros((L, batch, d), dtype),
        "cm_last": jnp.zeros((L, batch, d), dtype),
        "wkv": jnp.zeros((L, batch, H, K, K), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def _block(layer: Dict, cfg: ArchConfig, x: jnp.ndarray, tm_last, cm_last,
           wkv):
    a, new_tm_last, new_wkv = time_mix(
        layer["tm"], cfg, rms_norm(x, layer["ln1"], cfg.norm_eps),
        tm_last, wkv)
    x = x + a
    b, new_cm_last = channel_mix(
        layer["cm"], cfg, rms_norm(x, layer["ln2"], cfg.norm_eps), cm_last)
    return x + b, new_tm_last, new_cm_last, new_wkv


def forward_with_state(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                       state: Dict, remat: bool = True):
    """Full-sequence forward threading recurrent state (train & prefill).

    NOTE on shifts: state's tm_last/cm_last hold the *normalized* previous
    activation per layer (what the shift consumes)."""
    h = rms_norm(params["embed"][tokens], params["ln_in"], cfg.norm_eps)

    def scan_fn(x, layer_state):
        layer, tm_last, cm_last, wkv = layer_state
        ln1 = rms_norm(x, layer["ln1"], cfg.norm_eps)
        a, _, new_wkv = time_mix(layer["tm"], cfg, ln1, tm_last, wkv)
        new_tm_last = ln1[:, -1]
        x = x + a
        ln2 = rms_norm(x, layer["ln2"], cfg.norm_eps)
        b, _ = channel_mix(layer["cm"], cfg, ln2, cm_last)
        new_cm_last = ln2[:, -1]
        from .runtime_flags import constrain_residual
        return constrain_residual(x + b), (new_tm_last, new_cm_last,
                                           new_wkv)

    if remat:
        scan_fn = jax.checkpoint(scan_fn)
    h, (tm_lasts, cm_lasts, wkvs) = jax.lax.scan(
        scan_fn, h,
        (params["layers"], state["tm_last"], state["cm_last"], state["wkv"]))
    logits = rms_norm(h, params["final_norm"], cfg.norm_eps) @ params["unembed"]
    new_state = {"tm_last": tm_lasts, "cm_last": cm_lasts, "wkv": wkvs,
                 "idx": state["idx"] + tokens.shape[1]}
    return logits, new_state


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            remat: bool = True) -> jnp.ndarray:
    state = init_state(cfg, tokens.shape[0], params["embed"].dtype)
    logits, _ = forward_with_state(params, cfg, tokens, state, remat)
    return logits


def prefill(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            state: Dict, remat: bool = True):
    logits, new_state = forward_with_state(params, cfg, tokens, state, remat)
    return logits[:, -1], new_state


def decode_step(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                state: Dict):
    """tokens: (B,1)."""
    logits, new_state = forward_with_state(params, cfg, tokens, state,
                                           remat=False)
    return logits[:, 0], new_state

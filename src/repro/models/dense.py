"""Decoder-only dense transformer (llama family).

Covers the assigned archs: mistral-large-123b, llama3.2-3b, smollm-135m,
deepseek-7b — and serves as the language backbone of llava-next (vlm) and as
the transformer trunk reused by the MoE models (attention + norms).

All layers are stacked; the forward pass is one ``lax.scan`` with optional
``jax.checkpoint`` rematerialization so 88-layer graphs stay compact.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (gqa_decode_step, gqa_forward, gqa_prefill,
                        init_gqa_params, init_kv_cache)
from .common import (ArchConfig, KeyGen, Params, dense_init, embed_init,
                     rms_norm, stack_layer_params, swiglu)


def init_layer(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {
        "attn": init_gqa_params(kg, cfg, dtype),
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "w_gate": dense_init(kg(), (cfg.d_model, cfg.d_ff), dtype),
        "w_up": dense_init(kg(), (cfg.d_model, cfg.d_ff), dtype),
        "w_down": dense_init(kg(), (cfg.d_ff, cfg.d_model), dtype,
                             scale=cfg.d_ff ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }


def init_params(rng: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    cfg.validate()
    kg = KeyGen(rng)
    params = {
        "embed": embed_init(kg(), (cfg.vocab, cfg.d_model), dtype),
        "layers": stack_layer_params(
            functools.partial(init_layer, cfg=cfg, dtype=dtype),
            cfg.n_layers, kg),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(kg(), (cfg.d_model, cfg.vocab), dtype)
    return params


def layer_fwd(layer: Dict, cfg: ArchConfig, x: jnp.ndarray,
              positions: jnp.ndarray, causal: bool = True) -> jnp.ndarray:
    h = x + gqa_forward(layer["attn"], cfg,
                        rms_norm(x, layer["attn_norm"], cfg.norm_eps),
                        positions, causal=causal)
    h = h + swiglu(rms_norm(h, layer["mlp_norm"], cfg.norm_eps),
                   layer["w_gate"], layer["w_up"], layer["w_down"])
    return h


def _logits(params: Params, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["unembed"]


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            embeds: Optional[jnp.ndarray] = None,
            remat: bool = True) -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S, vocab).

    embeds: optional (B, S_ctx, d) prefix embeddings (VLM image tokens /
    audio frames) prepended before the token embeddings.
    """
    h = params["embed"][tokens]
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    from .runtime_flags import constrain_residual
    body = functools.partial(layer_fwd, cfg=cfg, positions=positions)
    if remat:
        body = jax.checkpoint(body)

    def scan_fn(carry, layer):
        # §Perf lever: sequence-parallel residual (shards the saved
        # per-layer activations over "model"; no-op unless enabled)
        return constrain_residual(body(layer, x=carry)), None

    h, _ = jax.lax.scan(scan_fn, h, params["layers"])
    return _logits(params, cfg, h)


# ------------------------------------------------------------------ serving
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32):
    return init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)


def prefill(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            cache: Dict, embeds: Optional[jnp.ndarray] = None,
            remat: bool = True) -> Tuple[jnp.ndarray, Dict]:
    """Run the prompt through the model, filling the cache.

    Returns (last-position logits (B, vocab), cache)."""
    h = params["embed"][tokens]
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def scan_fn(x, layer_kv):
        layer, k, v = layer_kv
        attn_out, nk, nv = gqa_prefill(
            k, v, layer["attn"], cfg,
            rms_norm(x, layer["attn_norm"], cfg.norm_eps), positions)
        h2 = x + attn_out
        h2 = h2 + swiglu(rms_norm(h2, layer["mlp_norm"], cfg.norm_eps),
                         layer["w_gate"], layer["w_up"], layer["w_down"])
        return h2, (nk, nv)

    if remat:
        scan_fn = jax.checkpoint(scan_fn)
    h, (ks, vs) = jax.lax.scan(scan_fn, h,
                               (params["layers"], cache["k"], cache["v"]))
    new_cache = {"k": ks, "v": vs,
                 "idx": jnp.asarray(S, jnp.int32)}
    logits = _logits(params, cfg, h[:, -1:])[:, 0]
    return logits, new_cache


def decode_step(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One AR decode step. tokens: (B, 1) -> logits (B, vocab)."""
    from .runtime_flags import FLAGS
    if FLAGS.decode_inplace:
        return decode_step_inplace(params, cfg, tokens, cache)
    h = params["embed"][tokens]
    idx = cache["idx"]

    def scan_fn(x, layer_kv):
        layer, k, v = layer_kv
        attn_out, nk, nv = gqa_decode_step(
            k, v, idx, layer["attn"], cfg,
            rms_norm(x, layer["attn_norm"], cfg.norm_eps))
        h2 = x + attn_out
        h2 = h2 + swiglu(rms_norm(h2, layer["mlp_norm"], cfg.norm_eps),
                         layer["w_gate"], layer["w_up"], layer["w_down"])
        return h2, (nk, nv)

    h, (ks, vs) = jax.lax.scan(scan_fn, h,
                               (params["layers"], cache["k"], cache["v"]))
    new_cache = {"k": ks, "v": vs, "idx": idx + 1}
    return _logits(params, cfg, h)[:, 0], new_cache


def decode_step_inplace(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                        cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """§Perf variant: the stacked KV cache is a scan CARRY updated with a
    token-sized dynamic_update_slice per layer, instead of re-stacking each
    layer's full cache as scan outputs.

    Baseline decode writes O(full cache) per step (the ys-stacking copies);
    this writes O(L * token) — the roofline memory floor becomes cache READ
    bound only. With jit donation the carry aliases the input buffer.
    """
    from .attention import _grouped_attention, _ring_slot_positions
    from .common import apply_rope, rope_freqs
    h = params["embed"][tokens]
    idx = cache["idx"]
    K, V = cache["k"], cache["v"]              # (L, B, M, Hkv, D)
    B = h.shape[0]
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    M = K.shape[2]
    slot = jnp.mod(idx, M)
    pos = jnp.full((B, 1), idx, jnp.int32)
    cos, sin = rope_freqs(pos, D, cfg.rope_theta)
    slot_pos = _ring_slot_positions(idx + 1, M)
    mask = jnp.where(slot_pos >= 0, 0.0, -1e30)[None, None, None, None, :]

    def scan_fn(carry, layer_i):
        x, K, V = carry
        layer, i = layer_i
        ap = layer["attn"]
        xn = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        q = apply_rope((xn @ ap["wq"]).reshape(B, 1, H, D), cos, sin)
        k = apply_rope((xn @ ap["wk"]).reshape(B, 1, Hkv, D), cos, sin)
        v = (xn @ ap["wv"]).reshape(B, 1, Hkv, D)
        # token-sized in-place writes into the stacked carry
        K = jax.lax.dynamic_update_slice(
            K, k[None], (i, 0, slot, 0, 0))
        V = jax.lax.dynamic_update_slice(
            V, v[None], (i, 0, slot, 0, 0))
        k_layer = jax.lax.dynamic_index_in_dim(K, i, 0, keepdims=False)
        v_layer = jax.lax.dynamic_index_in_dim(V, i, 0, keepdims=False)
        out = _grouped_attention(q, k_layer, v_layer, mask)
        x = x + out.reshape(B, 1, H * D) @ ap["wo"]
        x = x + swiglu(rms_norm(x, layer["mlp_norm"], cfg.norm_eps),
                       layer["w_gate"], layer["w_up"], layer["w_down"])
        return (x, K, V), None

    (h, K, V), _ = jax.lax.scan(
        scan_fn, (h, K, V),
        (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    new_cache = {"k": K, "v": V, "idx": idx + 1}
    return _logits(params, cfg, h)[:, 0], new_cache

"""Mamba2 (SSD — state-space duality) blocks, used by zamba2-2.7b.

TPU adaptation (see DESIGN.md §3): instead of the GPU implementation's
hardware-aware parallel scan over time (warp-level primitives), we use the
paper's own *chunked SSD* formulation — intra-chunk work becomes MXU-friendly
(L x L) matmuls and inter-chunk state passing is a short ``lax.scan`` over
S / L carries. This is the canonical TPU-native mapping of the algorithm.

Recurrence (per head h, head_dim P, state N):
  a_t   = exp(dt_t * A)                       (scalar decay per head/step)
  state = a_t * state + dt_t * x_t  (x)  B_t   -> (P, N)
  y_t   = state @ C_t + D * x_t

Chunked with chunk length L and within-chunk cumulated log-decay c_i:
  intra: Y[i] = sum_{j<=i} exp(c_i - c_j) (C_i . B_j) dt_j x_j
  state: S_c  = sum_j exp(c_L - c_j) dt_j x_j (x) B_j
  inter: H_c  = exp(c_L) H_{c-1} + S_c ;  Y[i] += exp(c_i) (C_i . H_{c-1})
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, dense_init, rms_norm

CHUNK = 128  # SSD chunk length (MXU-aligned)


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def init_mamba_params(kg: KeyGen, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    di = d_inner(cfg)
    H, N = n_ssm_heads(cfg), cfg.ssm_state
    conv_dim = di + 2 * N  # x, B, C go through the causal conv
    return {
        # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
        "w_in": dense_init(kg(), (d, 2 * di + 2 * N + H), dtype),
        "conv_w": dense_init(kg(), (cfg.ssm_conv, conv_dim), dtype,
                             scale=cfg.ssm_conv ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(kg(), (H,), jnp.float32) *
                    (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3)))
        ).astype(dtype),
        "gate_norm": jnp.ones((di,), dtype),
        "w_out": dense_init(kg(), (di, d), dtype),
    }


def _split_in(proj: jnp.ndarray, cfg: ArchConfig):
    di = d_inner(cfg)
    H, N = n_ssm_heads(cfg), cfg.ssm_state
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    B = proj[..., 2 * di:2 * di + N]
    C = proj[..., 2 * di + N:2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N:]
    return z, x, B, C, dt


def _causal_conv(seq: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over time. seq: (B,S,Cd); prev: (B,K-1,Cd)
    carry-in from the previous segment. Returns (out, new carry)."""
    K = w.shape[0]
    full = jnp.concatenate([prev, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i] for i in range(K))
    new_prev = full[:, full.shape[1] - (K - 1):]
    return jax.nn.silu(out + b), new_prev


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
                state0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. x: (Bt,S,H,P), dt: (Bt,S,H), A: (H,) negative,
    B/C: (Bt,S,N) (single group broadcast over heads), state0: (Bt,H,P,N).
    Returns (y (Bt,S,H,P), final state)."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    L = min(CHUNK, S)
    S_in = S
    if S % L:
        # pad with dt=0 steps: decay exp(0)=1 and zero input leave the
        # recurrence untouched; padded outputs are sliced off below.
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L

    xr = x.reshape(Bt, nc, L, H, P)
    dtr = dt.reshape(Bt, nc, L, H)
    Br = B.reshape(Bt, nc, L, N)
    Cr = C.reshape(Bt, nc, L, N)

    loga = dtr * A  # (Bt,nc,L,H), <= 0
    cum = jnp.cumsum(loga, axis=2)                      # within-chunk cumsum
    total = cum[:, :, -1]                                # (Bt,nc,H)

    # intra-chunk: M[i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j, j <= i
    scores = jnp.einsum("bcln,bcmn->bclm", Cr, Br)       # (Bt,nc,L,L)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (Bt,nc,L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(mask[None, None, :, :, None],
                  jnp.exp(decay), 0.0) * scores[..., None]
    y = jnp.einsum("bclmh,bcmh,bcmhp->bclhp", M, dtr, xr)

    # chunk summaries: S_c = sum_j exp(total - cum_j) dt_j x_j (x) B_j
    w_j = jnp.exp(total[:, :, None] - cum) * dtr          # (Bt,nc,L,H)
    chunk_states = jnp.einsum("bclh,bclhp,bcln->bchpn", w_j, xr, Br)

    # inter-chunk scan over carries
    def scan_fn(h_prev, inp):
        tot_c, s_c = inp                                  # (Bt,H), (Bt,H,P,N)
        h_new = jnp.exp(tot_c)[..., None, None] * h_prev + s_c
        return h_new, h_prev                              # emit state BEFORE

    tot_t = jnp.moveaxis(total, 1, 0)                     # (nc,Bt,H)
    st_t = jnp.moveaxis(chunk_states, 1, 0).astype(jnp.float32)
    final_state, h_before = jax.lax.scan(
        scan_fn, state0.astype(jnp.float32), (tot_t, st_t))
    h_before = jnp.moveaxis(h_before, 0, 1)               # (Bt,nc,H,P,N)

    # inter-chunk contribution: y[i] += exp(cum_i) * C_i . H_{c-1}
    y = y + jnp.einsum("bclh,bcln,bchpn->bclhp",
                       jnp.exp(cum), Cr, h_before)
    y = y + D[None, None, :, None] * xr
    return y.reshape(Bt, S, H, P)[:, :S_in], final_state


def mamba_forward(params: Dict, cfg: ArchConfig, x: jnp.ndarray,
                  conv_state: jnp.ndarray, ssm_state: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence Mamba2 block. x: (B,S,d).
    conv_state: (B,K-1,conv_dim); ssm_state: (B,H,P,N)."""
    Bt, S, _ = x.shape
    H, P, N = n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    proj = x @ params["w_in"]
    z, xs, Bmat, Cmat, dt = _split_in(proj, cfg)
    conv_in = jnp.concatenate([xs, Bmat, Cmat], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], conv_state)
    di = d_inner(cfg)
    xs = conv_out[..., :di].reshape(Bt, S, H, P)
    Bmat = conv_out[..., di:di + N]
    Cmat = conv_out[..., di + N:]
    dt = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, new_ssm = ssd_chunked(xs, dt, A, Bmat, Cmat, params["D"], ssm_state)
    y = y.reshape(Bt, S, di).astype(x.dtype)
    new_ssm = new_ssm.astype(ssm_state.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return y @ params["w_out"], new_conv, new_ssm


def mamba_decode_step(params: Dict, cfg: ArchConfig, x: jnp.ndarray,
                      conv_state: jnp.ndarray, ssm_state: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token state update. x: (B,1,d)."""
    Bt = x.shape[0]
    H, P, N = n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    di = d_inner(cfg)
    proj = x @ params["w_in"]
    z, xs, Bmat, Cmat, dt = _split_in(proj, cfg)
    conv_in = jnp.concatenate([xs, Bmat, Cmat], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], conv_state)
    xs = conv_out[:, 0, :di].reshape(Bt, H, P)
    Bv = conv_out[:, 0, di:di + N]
    Cv = conv_out[:, 0, di + N:]
    dtv = jax.nn.softplus(dt[:, 0] + params["dt_bias"])          # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dtv * A)                                          # (B,H)
    upd = (dtv[..., None] * xs)[..., None] * Bv[:, None, None, :]
    new_ssm = (a[..., None, None] * ssm_state + upd).astype(ssm_state.dtype)
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, Cv)
    y = y + params["D"][None, :, None] * xs
    y = y.reshape(Bt, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return y @ params["w_out"], new_conv, new_ssm


def init_mamba_state(cfg: ArchConfig, batch: int, dtype):
    H, P, N = n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = d_inner(cfg) + 2 * N
    return (jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
            jnp.zeros((batch, H, P, N), dtype))

"""Shared building blocks for all backbones: config, norms, RoPE, inits.

Everything is a pure function over explicit parameter pytrees (no flax in the
environment, and pure-function style keeps pjit sharding rules path-based).
Parameters for repeated layers are STACKED along a leading ``n_layers`` axis so
the forward pass is a single ``jax.lax.scan`` — this keeps the lowered HLO
small enough to compile 88-layer/123B-parameter graphs on one CPU host and
makes activation rematerialization a one-line ``jax.checkpoint``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture. Field names follow the assignment table."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""               # citation from the assignment table
    head_dim: Optional[int] = None  # default d_model // n_heads
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # --- MLA (deepseek-style latent attention) ---
    use_mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0            # hybrid: shared attn block period
    # --- enc-dec ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- vlm / audio frontends (stubs: embeddings arrive precomputed) ---
    n_ctx_embeds: int = 0          # image patch / audio frame token count
    # --- serving ---
    sliding_window: int = 0        # 0 = full attention

    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def validate(self) -> None:
        if self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError(f"{self.name}: n_heads % n_kv_heads != 0")
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if self.use_mla:
            assert self.kv_lora > 0


# ------------------------------------------------------------------ numerics
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale
            + bias)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU FFN used by every llama-family config."""
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def rope_freqs(positions: jnp.ndarray, dim: int,
               theta: float = 10000.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for rotary embeddings. positions: (..., S) int32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D). cos/sin: broadcastable (..., S, D/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal_time_embedding(t: jnp.ndarray, dim: int,
                              max_period: float = 10000.0) -> jnp.ndarray:
    """Transformer/DDPM sinusoidal embedding of (integer) timesteps."""
    half = dim // 2
    freqs = jnp.exp(-np.log(max_period) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb


# -------------------------------------------------------------------- inits
def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               scale: Optional[float] = None) -> jnp.ndarray:
    """Truncated-normal fan-in init (matches common LLM practice)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Sequential PRNG key dispenser for verbose init code."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


def stack_layer_params(layer_inits, n_layers: int, keygen: KeyGen):
    """Initialize per-layer params and stack along a leading axis.

    layer_inits: fn(key) -> pytree for ONE layer. Uses vmap over split keys so
    the stacked tree is created directly (no python-loop concat).
    """
    keys = jnp.stack([keygen() for _ in range(n_layers)])
    return jax.vmap(layer_inits)(keys)


def causal_mask(S: int, dtype=jnp.float32,
                window: int = 0) -> jnp.ndarray:
    """(S, S) additive mask; optional sliding window (local attention)."""
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = j <= i
    if window:
        ok &= j > i - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(dtype)


def count_params(params: Params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))

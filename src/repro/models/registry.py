"""Uniform model API over every architecture family.

Each family adapter exposes:
  init_params(rng, cfg, dtype)                     -> params
  forward(params, cfg, tokens, embeds=None, remat) -> (logits, aux_loss)
  init_cache(cfg, batch, max_len, dtype)           -> cache pytree
  prefill(params, cfg, tokens, cache, embeds=None) -> (last logits, cache)
  decode_step(params, cfg, tokens, cache)          -> (logits, cache)

`embeds` carries stub-frontend context (VLM patches / audio frames).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from . import dense, encdec, hybrid, moe, rwkv6, vlm
from .common import ArchConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init_params: Callable
    forward: Callable          # -> (logits, aux)
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    needs_embeds: bool = False  # stub frontend supplies `embeds`
    has_decode: bool = True


def _wrap_no_aux(fwd):
    def f(params, cfg, tokens, embeds=None, remat=True):
        return fwd(params, cfg, tokens, embeds=embeds, remat=remat), jnp.zeros(
            (), jnp.float32)
    return f


def _rwkv_forward(params, cfg, tokens, embeds=None, remat=True):
    assert embeds is None
    return rwkv6.forward(params, cfg, tokens, remat=remat), jnp.zeros(
        (), jnp.float32)


def _rwkv_cache(cfg, batch, max_len, dtype=jnp.float32):
    del max_len  # O(1) state — the whole point of rwkv at long context
    return rwkv6.init_state(cfg, batch, dtype)


def _rwkv_prefill(params, cfg, tokens, cache, embeds=None, remat=True):
    assert embeds is None
    return rwkv6.prefill(params, cfg, tokens, cache, remat=remat)


def _encdec_forward(params, cfg, tokens, embeds=None, remat=True):
    assert embeds is not None, "audio arch needs frame embeddings"
    return encdec.forward(params, cfg, tokens, embeds, remat=remat), jnp.zeros(
        (), jnp.float32)


def _encdec_cache(cfg, batch, max_len, dtype=jnp.float32):
    return encdec.init_cache(cfg, batch, max_len, cfg.n_ctx_embeds, dtype)


FAMILIES: Dict[str, ModelApi] = {
    "dense": ModelApi(dense.init_params, _wrap_no_aux(dense.forward),
                      dense.init_cache, dense.prefill, dense.decode_step),
    "moe": ModelApi(moe.init_params,
                    lambda p, c, t, embeds=None, remat=True: moe.forward(
                        p, c, t, embeds=embeds, remat=remat),
                    moe.init_cache, moe.prefill, moe.decode_step),
    "ssm": ModelApi(rwkv6.init_params, _rwkv_forward, _rwkv_cache,
                    _rwkv_prefill, rwkv6.decode_step),
    "hybrid": ModelApi(hybrid.init_params, _wrap_no_aux(hybrid.forward),
                       hybrid.init_cache, hybrid.prefill, hybrid.decode_step),
    "audio": ModelApi(encdec.init_params, _encdec_forward, _encdec_cache,
                      encdec.prefill, encdec.decode_step, needs_embeds=True),
    "vlm": ModelApi(vlm.init_params, _wrap_no_aux(vlm.forward),
                    vlm.init_cache, vlm.prefill, vlm.decode_step,
                    needs_embeds=True),
}


def get_api(cfg: ArchConfig) -> ModelApi:
    try:
        return FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for {cfg.name}")

"""Model zoo: every assigned architecture family + the paper's U-Net."""
from .common import ArchConfig, count_params
from .registry import FAMILIES, ModelApi, get_api
from . import attention, dense, encdec, hybrid, mamba2, moe, rwkv6, unet, vlm

__all__ = ["ArchConfig", "count_params", "FAMILIES", "ModelApi", "get_api",
           "attention", "dense", "encdec", "hybrid", "mamba2", "moe",
           "rwkv6", "unet", "vlm"]

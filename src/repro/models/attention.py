"""Attention variants: GQA (all dense archs), MLA (deepseek-v2), sliding
window, and the decode paths with KV / latent caches.

Layouts:
  activations  x: (B, S, d_model)
  q            : (B, S, H, D)
  k, v         : (B, S_kv, H_kv, D)
  KV cache     : dict(k=(B, M, H_kv, D), v=(B, M, H_kv, D), idx=int32 scalar)
                 M = max_len (full) or window size (ring buffer).
  MLA cache    : dict(ckv=(B, M, kv_lora), krope=(B, M, rope_dim), idx)

The einsum formulation here is the reference path; the Pallas flash-attention
kernel (repro.kernels.flash_attention) is a drop-in for the (train/prefill)
full-sequence case and is selected by the model configs' runtime flags.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (ArchConfig, KeyGen, apply_rope, causal_mask, dense_init,
                     rope_freqs)

Cache = Dict[str, jnp.ndarray]
_NEG = -1e30  # large-negative instead of -inf: safe under bf16 softmax


# =============================================================== GQA params
def init_gqa_params(keygen: KeyGen, cfg: ArchConfig, dtype) -> Dict:
    d, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    return {
        "wq": dense_init(keygen(), (d, H * D), dtype),
        "wk": dense_init(keygen(), (d, Hkv * D), dtype),
        "wv": dense_init(keygen(), (d, Hkv * D), dtype),
        "wo": dense_init(keygen(), (H * D, d), dtype),
    }


def _grouped_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       mask: jnp.ndarray) -> jnp.ndarray:
    """q: (B,Sq,H,D), k/v: (B,Sk,Hkv,D), mask additive broadcast to
    (B,Hkv,G,Sq,Sk). Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / jnp.sqrt(
        jnp.asarray(D, q.dtype))
    scores = scores + mask
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, D)


def chunked_grouped_attention(q: jnp.ndarray, k: jnp.ndarray,
                              v: jnp.ndarray, causal: bool,
                              q_chunk: int, k_chunk: int,
                              window: int = 0) -> jnp.ndarray:
    """Online-softmax attention with O(q_chunk * k_chunk) score blocks.

    Pure-JAX equivalent of the Pallas flash kernel (kernels/flash_attention)
    — XLA-lowerable everywhere, used to kill the S^2 score materialization
    that dominates the memory roofline term at 32k prefill (§Perf lever
    ``attn_chunk``). q: (B,Sq,H,D); k/v: (B,Sk,Hkv,D).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    G = H // Hkv
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    assert Sq % qc == 0 and Sk % kc == 0, (Sq, qc, Sk, kc)
    nq, nk = Sq // qc, Sk // kc
    qg = q.reshape(B, nq, qc, Hkv, G, D)
    kg = jnp.moveaxis(k.reshape(B, nk, kc, Hkv, D), 1, 0)
    vg = jnp.moveaxis(v.reshape(B, nk, kc, Hkv, D), 1, 0)
    scale = 1.0 / (D ** 0.5)

    def q_block(qi):
        qb = qg[:, qi] * jnp.asarray(scale, q.dtype)     # (B,qc,Hkv,G,D)

        def body(carry, inp):
            m, l, acc = carry
            kb, vb, ki = inp                              # (B,kc,Hkv,D)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qb, kb).astype(jnp.float32)
            rows = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
            cols = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
            ok = jnp.ones((qc, kc), bool)
            if causal:
                ok &= rows >= cols
            if window:
                ok &= cols > rows - window
            s = jnp.where(ok[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = alpha * acc + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(q.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qc, 1), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (kg, vg, jnp.arange(nk, dtype=jnp.int32)))
        out = acc / jnp.maximum(l, 1e-20)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)   # (B,qc,Hkv,G,D)

    blocks = jax.lax.map(q_block, jnp.arange(nq, dtype=jnp.int32))
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, Hkv, G, D)
    return out.reshape(B, Sq, H, D)


def gqa_forward(params: Dict, cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray, causal: bool = True,
                mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill). positions: (B, S)."""
    from .runtime_flags import FLAGS
    B, S, d = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    q = (x @ params["wq"]).reshape(B, S, H, D)
    k = (x @ params["wk"]).reshape(B, S, Hkv, D)
    v = (x @ params["wv"]).reshape(B, S, Hkv, D)
    cos, sin = rope_freqs(positions, D, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if (FLAGS.attn_chunk and mask is None and S > FLAGS.attn_chunk
            and S % FLAGS.attn_chunk == 0):
        out = chunked_grouped_attention(q, k, v, causal, FLAGS.attn_chunk,
                                        FLAGS.attn_chunk,
                                        window=cfg.sliding_window)
        return out.reshape(B, S, H * D) @ params["wo"]
    if mask is None:
        if causal:
            mask = causal_mask(S, jnp.float32, cfg.sliding_window)
        else:
            mask = jnp.zeros((S, S), jnp.float32)
    mask = jnp.maximum(mask, _NEG)
    out = _grouped_attention(q, k, v, mask)
    return out.reshape(B, S, H * D) @ params["wo"]


def gqa_cross_forward(params: Dict, cfg: ArchConfig, x: jnp.ndarray,
                      kv_src: jnp.ndarray) -> jnp.ndarray:
    """Cross-attention (enc-dec decoder): queries from x, keys/values from
    kv_src (encoder output). No RoPE across modalities, no causal mask."""
    B, S, _ = x.shape
    Sk = kv_src.shape[1]
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    q = (x @ params["wq"]).reshape(B, S, H, D)
    k = (kv_src @ params["wk"]).reshape(B, Sk, Hkv, D)
    v = (kv_src @ params["wv"]).reshape(B, Sk, Hkv, D)
    mask = jnp.zeros((S, Sk), jnp.float32)
    out = _grouped_attention(q, k, v, mask)
    return out.reshape(B, S, H * D) @ params["wo"]


# ---------------------------------------------------------------- KV cache
def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, n_layers: int,
                  dtype) -> Cache:
    """Stacked-over-layers KV cache. For sliding-window configs the buffer is
    a ring of size ``min(window, max_len)``."""
    M = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    Hkv, D = cfg.n_kv_heads, cfg.hd()
    return {
        "k": jnp.zeros((n_layers, batch, M, Hkv, D), dtype),
        "v": jnp.zeros((n_layers, batch, M, Hkv, D), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def _ring_slot_positions(idx: jnp.ndarray, M: int) -> jnp.ndarray:
    """Absolute position held by each ring slot after ``idx`` writes.

    Slot i holds position p = n - ((n - i) mod M) with n = idx - 1 (the last
    written position); p < 0 means the slot is still empty.
    """
    n = idx - 1
    i = jnp.arange(M)
    return n - jnp.mod(n - i, M)


def gqa_decode_step(layer_k: jnp.ndarray, layer_v: jnp.ndarray,
                    idx: jnp.ndarray, params: Dict, cfg: ArchConfig,
                    x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray,
                                             jnp.ndarray]:
    """One decode step for ONE layer.

    layer_k/layer_v: (B, M, Hkv, D) this layer's cache; idx: tokens written so
    far (== position of the incoming token). x: (B, 1, d).
    Returns (attn_out (B,1,d), new_k, new_v).
    """
    B = x.shape[0]
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    M = layer_k.shape[1]
    pos = jnp.full((B, 1), idx, jnp.int32)
    q = (x @ params["wq"]).reshape(B, 1, H, D)
    k = (x @ params["wk"]).reshape(B, 1, Hkv, D)
    v = (x @ params["wv"]).reshape(B, 1, Hkv, D)
    cos, sin = rope_freqs(pos, D, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = jnp.mod(idx, M)
    new_k = jax.lax.dynamic_update_slice_in_dim(layer_k, k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(layer_v, v, slot, axis=1)
    slot_pos = _ring_slot_positions(idx + 1, M)          # (M,)
    valid = slot_pos >= 0
    mask = jnp.where(valid, 0.0, _NEG)[None, None, None, None, :]
    out = _grouped_attention(q, new_k, new_v, mask)
    return out.reshape(B, 1, H * D) @ params["wo"], new_k, new_v


def gqa_prefill(layer_k: jnp.ndarray, layer_v: jnp.ndarray, params: Dict,
                cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                 jnp.ndarray]:
    """Full-sequence prefill for one layer, writing the cache.

    Assumes prompt length S <= M (or window); writes rows [0, S)."""
    B, S, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    M = layer_k.shape[1]
    q = (x @ params["wq"]).reshape(B, S, H, D)
    k = (x @ params["wk"]).reshape(B, S, Hkv, D)
    v = (x @ params["wv"]).reshape(B, S, Hkv, D)
    cos, sin = rope_freqs(positions, D, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    from .runtime_flags import FLAGS
    if (FLAGS.attn_chunk and S > FLAGS.attn_chunk
            and S % FLAGS.attn_chunk == 0):
        out = chunked_grouped_attention(q, k, v, True, FLAGS.attn_chunk,
                                        FLAGS.attn_chunk,
                                        window=cfg.sliding_window)
    else:
        mask = jnp.maximum(causal_mask(S, jnp.float32, cfg.sliding_window),
                           _NEG)
        out = _grouped_attention(q, k, v, mask)
    if S >= M:
        new_k, new_v = k[:, S - M:], v[:, S - M:]
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(layer_k, k, 0, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(layer_v, v, 0, axis=1)
    return out.reshape(B, S, H * D) @ params["wo"], new_k, new_v


# ====================================================================== MLA
def init_mla_params(keygen: KeyGen, cfg: ArchConfig, dtype) -> Dict:
    """Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

    K/V are compressed into a ``kv_lora``-dim latent c_kv; decode caches only
    (c_kv, k_rope) — the paper's 93% KV-cache reduction. Queries optionally
    go through their own low-rank bottleneck (q_lora).
    """
    d, H = cfg.d_model, cfg.n_heads
    qk_nope, qk_rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qd = qk_nope + qk_rope
    p = {
        "w_dkv": dense_init(keygen(), (d, cfg.kv_lora), dtype),
        "w_krope": dense_init(keygen(), (d, qk_rope), dtype),
        "kv_norm": jnp.ones((cfg.kv_lora,), dtype),
        "w_uk": dense_init(keygen(), (cfg.kv_lora, H * qk_nope), dtype),
        "w_uv": dense_init(keygen(), (cfg.kv_lora, H * dv), dtype),
        "wo": dense_init(keygen(), (H * dv, d), dtype),
    }
    if cfg.q_lora:
        p["w_dq"] = dense_init(keygen(), (d, cfg.q_lora), dtype)
        p["q_norm"] = jnp.ones((cfg.q_lora,), dtype)
        p["w_uq"] = dense_init(keygen(), (cfg.q_lora, H * qd), dtype)
    else:
        p["wq"] = dense_init(keygen(), (d, H * qd), dtype)
    return p


def _mla_q(params: Dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    from .common import rms_norm
    B, S, _ = x.shape
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora:
        cq = rms_norm(x @ params["w_dq"], params["q_norm"], cfg.norm_eps)
        q = cq @ params["w_uq"]
    else:
        q = x @ params["wq"]
    return q.reshape(B, S, H, qd)


def _mla_attend(params: Dict, cfg: ArchConfig, q: jnp.ndarray,
                ckv: jnp.ndarray, krope: jnp.ndarray,
                mask: jnp.ndarray, positions_q: jnp.ndarray,
                positions_k: jnp.ndarray) -> jnp.ndarray:
    """Shared MLA attention math. q: (B,Sq,H,qd); ckv: (B,Sk,kv_lora);
    krope: (B,Sk,rope)."""
    B, Sq, H, _ = q.shape
    Sk = ckv.shape[1]
    nope, rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos_q, sin_q = rope_freqs(positions_q, rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos_q, sin_q)
    cos_k, sin_k = rope_freqs(positions_k, rope, cfg.rope_theta)
    k_rope = apply_rope(krope[:, :, None, :], cos_k, sin_k)[:, :, 0]
    k_nope = (ckv @ params["w_uk"]).reshape(B, Sk, H, nope)
    v = (ckv @ params["w_uv"]).reshape(B, Sk, H, dv)
    scale = 1.0 / jnp.sqrt(jnp.asarray(nope + rope, q.dtype))
    scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope) +
              jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope)) * scale
    scores = scores + mask
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return out.reshape(B, Sq, H * dv) @ params["wo"]


def mla_forward(params: Dict, cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray) -> jnp.ndarray:
    from .common import rms_norm
    B, S, _ = x.shape
    q = _mla_q(params, cfg, x)
    ckv = rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)
    krope = x @ params["w_krope"]
    mask = jnp.maximum(causal_mask(S, jnp.float32, cfg.sliding_window), _NEG)
    return _mla_attend(params, cfg, q, ckv, krope, mask, positions, positions)


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, n_layers: int,
                   dtype) -> Cache:
    M = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    return {
        "ckv": jnp.zeros((n_layers, batch, M, cfg.kv_lora), dtype),
        "krope": jnp.zeros((n_layers, batch, M, cfg.qk_rope_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def mla_decode_step(layer_ckv: jnp.ndarray, layer_krope: jnp.ndarray,
                    idx: jnp.ndarray, params: Dict, cfg: ArchConfig,
                    x: jnp.ndarray):
    from .common import rms_norm
    B = x.shape[0]
    M = layer_ckv.shape[1]
    q = _mla_q(params, cfg, x)                               # (B,1,H,qd)
    ckv_new = rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)
    krope_new = x @ params["w_krope"]
    slot = jnp.mod(idx, M)
    new_ckv = jax.lax.dynamic_update_slice_in_dim(layer_ckv, ckv_new, slot, 1)
    new_krope = jax.lax.dynamic_update_slice_in_dim(layer_krope, krope_new,
                                                    slot, 1)
    slot_pos = _ring_slot_positions(idx + 1, M)
    mask = jnp.where(slot_pos >= 0, 0.0, _NEG)[None, None, None, :]
    pos_q = jnp.full((B, 1), idx, jnp.int32)
    pos_k = jnp.broadcast_to(jnp.maximum(slot_pos, 0)[None], (B, M))
    out = _mla_attend(params, cfg, q, new_ckv, new_krope, mask, pos_q, pos_k)
    return out, new_ckv, new_krope


def mla_prefill(layer_ckv: jnp.ndarray, layer_krope: jnp.ndarray,
                params: Dict, cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray):
    from .common import rms_norm
    B, S, _ = x.shape
    M = layer_ckv.shape[1]
    q = _mla_q(params, cfg, x)
    ckv = rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)
    krope = x @ params["w_krope"]
    mask = jnp.maximum(causal_mask(S, jnp.float32, cfg.sliding_window), _NEG)
    out = _mla_attend(params, cfg, q, ckv, krope, mask, positions, positions)
    if S >= M:
        new_ckv, new_krope = ckv[:, S - M:], krope[:, S - M:]
    else:
        new_ckv = jax.lax.dynamic_update_slice_in_dim(layer_ckv, ckv, 0, 1)
        new_krope = jax.lax.dynamic_update_slice_in_dim(layer_krope, krope,
                                                        0, 1)
    return out, new_ckv, new_krope

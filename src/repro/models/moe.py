"""Mixture-of-Experts models: kimi-k2 (GQA attn, 384 routed experts top-8)
and deepseek-v2 (MLA attention, 2 shared + 160 routed top-6).

Dispatch is the GShard/Switch grouped-capacity formulation: tokens are split
into groups of ``MOE_GROUP`` and routed with a per-group capacity
``C = ceil(top_k * group * capacity_factor / E)``. The dispatch/combine
einsums contract a (G, S, E, C) one-hot against token activations — under a
mesh with experts sharded on the "model" axis and groups on "data", XLA GSPMD
lowers these einsums to all-to-all collectives (verified in the dry-run HLO;
this is the collective the roofline analysis attributes to MoE).

Overflow tokens beyond capacity are dropped (their combine weight is zero and
the residual path carries them) — standard for capacity-based routing.

Layer 0 is a dense-FFN layer (both source models do this: "first_k_dense=1"),
handled outside the scanned MoE stack.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import dense as dense_model
from .attention import (gqa_decode_step, gqa_forward, gqa_prefill,
                        init_gqa_params, init_kv_cache, init_mla_cache,
                        init_mla_params, mla_decode_step, mla_forward,
                        mla_prefill)
from .common import (ArchConfig, KeyGen, Params, dense_init, embed_init,
                     rms_norm, stack_layer_params, swiglu)

MOE_GROUP = 512  # tokens per routing group (GShard's G axis); see DESIGN.md


# ------------------------------------------------------------------ routing
def _capacity(cfg: ArchConfig, group: int) -> int:
    import math
    return max(1, math.ceil(cfg.top_k * group * cfg.capacity_factor /
                            cfg.n_experts))


def route(router_w: jnp.ndarray, x: jnp.ndarray, cfg: ArchConfig,
          capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compute dispatch/combine tensors for grouped tokens.

    x: (G, S, d). Returns (dispatch (G,S,E,C) in x.dtype, combine same,
    aux_loss scalar)."""
    G, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("gsd,de->gse", x, router_w.astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                      # (G,S,K)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)       # renormalize

    # Switch-style load-balance auxiliary loss: E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], E), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    dispatch = jnp.zeros((G, S, E, capacity), x.dtype)
    combine = jnp.zeros((G, S, E, capacity), x.dtype)
    # occupancy counter per expert, accumulated across the K choices
    counts = jnp.zeros((G, E), jnp.int32)
    for j in range(K):
        onehot = jax.nn.one_hot(topi[..., j], E, dtype=jnp.int32)  # (G,S,E)
        pos = jnp.cumsum(onehot, axis=1) - 1 + counts[:, None, :]
        keep = (pos < capacity) & (onehot > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                                dtype=x.dtype)                 # OOB -> zeros
        sel = (onehot * keep).astype(x.dtype)[..., None] * pos_oh
        dispatch = dispatch + sel
        combine = combine + sel * topw[..., j, None, None].astype(x.dtype)
        counts = counts + jnp.sum(onehot * keep, axis=1)
    return dispatch, combine, aux


def moe_ffn(block: Dict, cfg: ArchConfig, x: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE feed-forward over (B, S, d) activations. Returns (out, aux)."""
    from .runtime_flags import FLAGS, constrain
    B, S, d = x.shape
    N = B * S
    group = min(FLAGS.moe_group or MOE_GROUP, N)
    G = N // group
    rem = N - G * group  # guard: pad to a multiple of the group size
    xt = x.reshape(N, d)
    if rem:
        xt = jnp.pad(xt, ((0, group - rem), (0, 0)))
        G += 1
    xg = xt.reshape(G, group, d)
    C = _capacity(cfg, group)
    dispatch, combine, aux = route(block["router"], xg, cfg, C)
    # §Perf lever: shard the routing one-hots' E dim over "model" so the
    # expert input is BORN expert-sharded (replaces the exp_in all-to-all
    # with a much smaller all-gather of x over the model axis)
    dispatch = constrain(dispatch, FLAGS.dispatch_spec)
    combine = constrain(combine, FLAGS.dispatch_spec)
    # (G,S,E,C) x (G,S,d) -> (E,G,C,d): the all-to-all under expert sharding
    exp_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
    # §Perf lever: pin the expert-parallel boundary (E->model, G->data)
    exp_in = constrain(exp_in, FLAGS.exp_in_spec)
    h = jnp.einsum("egcd,edf->egcf", exp_in, block["w_gate"])
    u = jnp.einsum("egcd,edf->egcf", exp_in, block["w_up"])
    h = jax.nn.silu(h) * u
    exp_out = jnp.einsum("egcf,efd->egcd", h, block["w_down"])
    y = jnp.einsum("gsec,egcd->gsd", combine, exp_out)
    y = y.reshape(-1, d)[:N].reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + swiglu(x, block["sw_gate"], block["sw_up"], block["sw_down"])
    return y, aux


# ------------------------------------------------------------------- params
def init_moe_block(kg: KeyGen, cfg: ArchConfig, dtype) -> Dict:
    d, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    block = {
        "router": dense_init(kg(), (d, E), jnp.float32),  # router in f32
        "w_gate": dense_init(kg(), (E, d, F), dtype),
        "w_up": dense_init(kg(), (E, d, F), dtype),
        "w_down": dense_init(kg(), (E, F, d), dtype,
                             scale=F ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.n_shared_experts:
        Fs = cfg.d_ff_expert * cfg.n_shared_experts
        block["sw_gate"] = dense_init(kg(), (d, Fs), dtype)
        block["sw_up"] = dense_init(kg(), (d, Fs), dtype)
        block["sw_down"] = dense_init(kg(), (Fs, d), dtype)
    return block


def _init_attn(kg: KeyGen, cfg: ArchConfig, dtype) -> Dict:
    if cfg.use_mla:
        return init_mla_params(kg, cfg, dtype)
    return init_gqa_params(kg, cfg, dtype)


def init_layer(key: jax.Array, cfg: ArchConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {
        "attn": _init_attn(kg, cfg, dtype),
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "moe": init_moe_block(kg, cfg, dtype),
    }


def init_params(rng: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    cfg.validate()
    kg = KeyGen(rng)
    # layer 0: dense FFN (first_k_dense = 1)
    dense0 = {
        "attn": _init_attn(kg, cfg, dtype),
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "w_gate": dense_init(kg(), (cfg.d_model, cfg.d_ff), dtype),
        "w_up": dense_init(kg(), (cfg.d_model, cfg.d_ff), dtype),
        "w_down": dense_init(kg(), (cfg.d_ff, cfg.d_model), dtype),
    }
    return {
        "embed": embed_init(kg(), (cfg.vocab, cfg.d_model), dtype),
        "layer0": dense0,
        "layers": stack_layer_params(
            functools.partial(init_layer, cfg=cfg, dtype=dtype),
            cfg.n_layers - 1, kg),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "unembed": dense_init(kg(), (cfg.d_model, cfg.vocab), dtype),
    }


# ------------------------------------------------------------------ forward
def _attn_fwd(layer: Dict, cfg: ArchConfig, x: jnp.ndarray,
              positions: jnp.ndarray) -> jnp.ndarray:
    xn = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
    if cfg.use_mla:
        return mla_forward(layer["attn"], cfg, xn, positions)
    return gqa_forward(layer["attn"], cfg, xn, positions)


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            embeds: Optional[jnp.ndarray] = None, remat: bool = True,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits, aux_loss)."""
    h = params["embed"][tokens]
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    l0 = params["layer0"]
    h = h + _attn_fwd(l0, cfg, h, positions)
    h = h + swiglu(rms_norm(h, l0["mlp_norm"], cfg.norm_eps),
                   l0["w_gate"], l0["w_up"], l0["w_down"])

    from .runtime_flags import constrain_residual

    def scan_fn(x, layer):
        x = x + _attn_fwd(layer, cfg, x, positions)
        y, aux = moe_ffn(layer["moe"], cfg,
                         rms_norm(x, layer["mlp_norm"], cfg.norm_eps))
        return constrain_residual(x + y), aux

    if remat:
        scan_fn = jax.checkpoint(scan_fn)
    h, auxes = jax.lax.scan(scan_fn, h, params["layers"])
    logits = rms_norm(h, params["final_norm"], cfg.norm_eps) @ params["unembed"]
    return logits, jnp.mean(auxes)


# ------------------------------------------------------------------ serving
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32):
    if cfg.use_mla:
        return init_mla_cache(cfg, batch, max_len, cfg.n_layers, dtype)
    return init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)


def _split_cache(cache: Dict):
    """layer-0 slice + stacked remainder of every cache array."""
    first = {k: v[0] for k, v in cache.items() if k != "idx"}
    rest = {k: v[1:] for k, v in cache.items() if k != "idx"}
    return first, rest


def prefill(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            cache: Dict, embeds: Optional[jnp.ndarray] = None,
            remat: bool = True) -> Tuple[jnp.ndarray, Dict]:
    h = params["embed"][tokens]
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    c0, crest = _split_cache(cache)

    l0 = params["layer0"]
    xn = rms_norm(h, l0["attn_norm"], cfg.norm_eps)
    if cfg.use_mla:
        attn_out, a0, b0 = mla_prefill(c0["ckv"], c0["krope"], l0["attn"],
                                       cfg, xn, positions)
    else:
        attn_out, a0, b0 = gqa_prefill(c0["k"], c0["v"], l0["attn"], cfg, xn,
                                       positions)
    h = h + attn_out
    h = h + swiglu(rms_norm(h, l0["mlp_norm"], cfg.norm_eps),
                   l0["w_gate"], l0["w_up"], l0["w_down"])

    def scan_fn(x, layer_kv):
        layer, ca, cb = layer_kv
        xn = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        if cfg.use_mla:
            attn_out, na, nb = mla_prefill(ca, cb, layer["attn"], cfg, xn,
                                           positions)
        else:
            attn_out, na, nb = gqa_prefill(ca, cb, layer["attn"], cfg, xn,
                                           positions)
        x = x + attn_out
        y, _ = moe_ffn(layer["moe"], cfg,
                       rms_norm(x, layer["mlp_norm"], cfg.norm_eps))
        return x + y, (na, nb)

    if remat:
        scan_fn = jax.checkpoint(scan_fn)
    names = ("ckv", "krope") if cfg.use_mla else ("k", "v")
    h, (nas, nbs) = jax.lax.scan(
        scan_fn, h, (params["layers"], crest[names[0]], crest[names[1]]))
    new_cache = {
        names[0]: jnp.concatenate([a0[None], nas], axis=0),
        names[1]: jnp.concatenate([b0[None], nbs], axis=0),
        "idx": jnp.asarray(S, jnp.int32),
    }
    logits = (rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
              @ params["unembed"])[:, 0]
    return logits, new_cache


def decode_step(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    h = params["embed"][tokens]
    idx = cache["idx"]
    c0, crest = _split_cache(cache)

    def attn_step(layer, ca, cb, x):
        xn = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        if cfg.use_mla:
            return mla_decode_step(ca, cb, idx, layer["attn"], cfg, xn)
        return gqa_decode_step(ca, cb, idx, layer["attn"], cfg, xn)

    l0 = params["layer0"]
    attn_out, a0, b0 = attn_step(l0, *(
        (c0["ckv"], c0["krope"]) if cfg.use_mla else (c0["k"], c0["v"])), h)
    h = h + attn_out
    h = h + swiglu(rms_norm(h, l0["mlp_norm"], cfg.norm_eps),
                   l0["w_gate"], l0["w_up"], l0["w_down"])

    def scan_fn(x, layer_kv):
        layer, ca, cb = layer_kv
        attn_out, na, nb = attn_step(layer, ca, cb, x)
        x = x + attn_out
        y, _ = moe_ffn(layer["moe"], cfg,
                       rms_norm(x, layer["mlp_norm"], cfg.norm_eps))
        return x + y, (na, nb)

    names = ("ckv", "krope") if cfg.use_mla else ("k", "v")
    h, (nas, nbs) = jax.lax.scan(
        scan_fn, h, (params["layers"], crest[names[0]], crest[names[1]]))
    new_cache = {
        names[0]: jnp.concatenate([a0[None], nas], axis=0),
        names[1]: jnp.concatenate([b0[None], nbs], axis=0),
        "idx": idx + 1,
    }
    logits = (rms_norm(h, params["final_norm"], cfg.norm_eps)
              @ params["unembed"])[:, 0]
    return logits, new_cache

"""Runtime performance flags (the §Perf hillclimb levers).

All default OFF so the paper-faithful / baseline path is unchanged; the
dry-run's --opt mode (and real launches) enable them. Flags are process-
global with a context manager so jitted closures pick them up at trace
time.

Levers:
  * seq_parallel_spec: PartitionSpec applied to the residual stream between
    layers (sequence parallelism — Korthikanti et al. adapted to GSPMD).
    Baseline GSPMD keeps the (B, S, d) carry replicated over "model", so
    the per-layer saved activations for backward are ~n_layers * B*S*d per
    device — over HBM for the 123B config. Constraining S onto "model"
    cuts that by the model-axis size for one extra all-gather per layer.
  * attn_chunk: KV-block size for chunked (online-softmax) attention in
    pure JAX. Kills the S^2 score materialization (the memory-term killer
    at 32k prefill); the XLA-level equivalent of the Pallas flash kernel,
    used where Mosaic isn't available (CPU dry-run) or as the lowering the
    TPU kernel replaces.
  * moe_group: routing group size (GShard G axis); smaller groups shrink
    the (G,S,E,C) dispatch one-hots at slightly higher drop risk.
  * exp_in_spec: sharding constraint for the MoE expert input tensor
    (E,G,C,d) — forces the all-to-all boundary instead of leaving GSPMD to
    choose (it sometimes all-gathers).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional

import jax


@dataclasses.dataclass
class PerfFlags:
    seq_parallel_spec: Optional[Any] = None    # PartitionSpec or None
    attn_chunk: int = 0                        # 0 = full S^2 attention
    moe_group: int = 512
    exp_in_spec: Optional[Any] = None
    dispatch_spec: Optional[Any] = None        # (G,S,E,C) routing one-hots
    decode_inplace: bool = False               # carry-cache decode variant
    mesh: Optional[Any] = None                 # Mesh for NamedSharding
    accum_steps: int = 1                       # grad-accum microbatching


FLAGS = PerfFlags()


@contextlib.contextmanager
def perf_flags(**kw):
    global FLAGS
    old = dataclasses.replace(FLAGS)
    for k, v in kw.items():
        setattr(FLAGS, k, v)
    try:
        yield FLAGS
    finally:
        FLAGS = old


def constrain(x, spec):
    """Sharding constraint; requires FLAGS.mesh (explicit NamedSharding —
    a bare PartitionSpec under `with mesh:` silently no-ops, which cost us
    a §Perf iteration to discover; see EXPERIMENTS.md)."""
    if spec is None or FLAGS.mesh is None:
        return x
    from jax.sharding import NamedSharding
    # drop axis entries for dims that don't divide (mirrors rules._divisible)
    import numpy as np
    fixed = []
    for dim, ax in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([FLAGS.mesh.shape[a] for a in axes]))
        fixed.append(ax if dim % size == 0 else None)
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(FLAGS.mesh, P(*fixed)))


def constrain_residual(x):
    """Apply the sequence-parallel constraint to a (B, S, d) carry."""
    return constrain(x, FLAGS.seq_parallel_spec)

from .model import (DiffusionLMConfig, init_params, eps_forward, make_eps_fn,
                    make_tile_eps_fn,
                    embed_tokens, round_to_tokens, training_loss,
                    generate)

__all__ = ["DiffusionLMConfig", "init_params", "eps_forward", "make_eps_fn",
           "make_tile_eps_fn",
           "embed_tokens", "round_to_tokens", "training_loss", "generate"]

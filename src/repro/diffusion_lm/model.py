"""DDIM over sequences: any assigned backbone family as the eps-network.

This carries the paper's technique to the assigned (non-image) architectures
(DESIGN.md §4): tokens are embedded into a continuous latent sequence
(Diffusion-LM style, Li et al. 2022), the forward diffusion of core/ runs on
those latents, and a backbone trunk with additive time conditioning predicts
the noise. Because training only uses the marginals q(x_t|x0) (the paper's
key observation), the SAME trained trunk serves every member of the
generalized family — DDPM, DDIM, and every eta in between — and the
accelerated tau trajectories give the 10-50x sampling speedup on sequence
generation too.

Trunk per family:
  dense / vlm / audio -> bidirectional dense transformer layers
  moe                 -> bidirectional attention + routed-expert FFN
  ssm (rwkv6)         -> rwkv6 layers (causal recurrence; noted in DESIGN.md)
  hybrid (zamba2)     -> mamba2 layers + shared attention (causal)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import NoiseSchedule, SamplerConfig, sample
from repro.core.diffusion import q_sample
from repro.models import dense, moe, rwkv6
from repro.models.common import (ArchConfig, KeyGen, Params, dense_init,
                                 embed_init, rms_norm,
                                 sinusoidal_time_embedding,
                                 stack_layer_params)


@dataclasses.dataclass(frozen=True)
class DiffusionLMConfig:
    arch: ArchConfig
    time_dim: int = 256
    latent_dim: int = 32           # Diffusion-LM: diffuse in a SMALL latent
    self_condition: bool = False   # beyond-paper option (off by default)

    @property
    def d(self) -> int:
        return self.latent_dim


def init_params(rng: jax.Array, cfg: DiffusionLMConfig,
                dtype=jnp.float32) -> Params:
    a = cfg.arch
    kg = KeyGen(rng)
    params: Params = {
        "embed": embed_init(kg(), (a.vocab, cfg.latent_dim), dtype),
        "w_in": dense_init(kg(), (cfg.latent_dim, a.d_model), dtype),
        "time_w1": dense_init(kg(), (cfg.time_dim, cfg.time_dim), dtype),
        "time_w2": dense_init(kg(), (cfg.time_dim, a.d_model), dtype),
        "out_norm": jnp.ones((a.d_model,), dtype),
        "w_out": dense_init(kg(), (a.d_model, cfg.latent_dim), dtype),
        "rounding": dense_init(kg(), (cfg.latent_dim, a.vocab), dtype),
    }
    if a.family in ("dense", "vlm", "audio"):
        params["layers"] = stack_layer_params(
            functools.partial(dense.init_layer, cfg=a, dtype=dtype),
            a.n_layers, kg)
    elif a.family == "moe":
        params["layers"] = stack_layer_params(
            functools.partial(moe.init_layer, cfg=a, dtype=dtype),
            a.n_layers, kg)
    elif a.family == "ssm":
        params["layers"] = stack_layer_params(
            functools.partial(rwkv6.init_layer, cfg=a, dtype=dtype),
            a.n_layers, kg)
    elif a.family == "hybrid":
        from repro.models import hybrid as hy
        params["layers"] = stack_layer_params(
            functools.partial(hy.init_mamba_layer, cfg=a, dtype=dtype),
            a.n_layers, kg)
    else:
        raise ValueError(a.family)
    return params


def _trunk(params: Params, cfg: DiffusionLMConfig, h: jnp.ndarray,
           remat: bool) -> jnp.ndarray:
    a = cfg.arch
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    if a.family in ("dense", "vlm", "audio"):
        def scan_fn(x, layer):
            return dense.layer_fwd(layer, a, x, positions, causal=False), None
    elif a.family == "moe":
        def scan_fn(x, layer):
            xn = rms_norm(x, layer["attn_norm"], a.norm_eps)
            from repro.models.attention import gqa_forward, mla_forward
            if a.use_mla:
                x = x + mla_forward(layer["attn"], a, xn, positions)
            else:
                x = x + gqa_forward(layer["attn"], a, xn, positions,
                                    causal=False)
            y, _ = moe.moe_ffn(layer["moe"], a,
                               rms_norm(x, layer["mlp_norm"], a.norm_eps))
            return x + y, None
    elif a.family == "ssm":
        def scan_fn(x, layer):
            st = rwkv6.init_state(a, B, x.dtype)
            ln1 = rms_norm(x, layer["ln1"], a.norm_eps)
            out, _, _ = rwkv6.time_mix(layer["tm"], a, ln1,
                                       st["tm_last"][0], st["wkv"][0])
            x = x + out
            ln2 = rms_norm(x, layer["ln2"], a.norm_eps)
            out, _ = rwkv6.channel_mix(layer["cm"], a, ln2, st["cm_last"][0])
            return x + out, None
    elif a.family == "hybrid":
        from repro.models import mamba2
        def scan_fn(x, layer):
            conv, ssm = mamba2.init_mamba_state(a, B, x.dtype)
            y, _, _ = mamba2.mamba_forward(
                layer["mamba"], a, rms_norm(x, layer["norm"], a.norm_eps),
                conv, ssm)
            return x + y, None
    else:
        raise ValueError(a.family)

    if remat:
        scan_fn = jax.checkpoint(scan_fn)
    h, _ = jax.lax.scan(scan_fn, h, params["layers"])
    return h


def eps_forward(params: Params, cfg: DiffusionLMConfig, x_t: jnp.ndarray,
                t: jnp.ndarray, remat: bool = True) -> jnp.ndarray:
    """eps prediction over latent sequences. x_t: (B,S,d); t: (B,) int32."""
    temb = sinusoidal_time_embedding(t, cfg.time_dim).astype(x_t.dtype)
    temb = jax.nn.silu(temb @ params["time_w1"]) @ params["time_w2"]
    h = x_t @ params["w_in"] + temb[:, None, :]
    h = _trunk(params, cfg, h, remat)
    h = rms_norm(h, params["out_norm"], cfg.arch.norm_eps)
    return h @ params["w_out"]


def make_eps_fn(params: Params, cfg: DiffusionLMConfig, remat: bool = False):
    def eps_fn(x, t):
        return eps_forward(params, cfg, x, t, remat=remat)
    return eps_fn


def make_tile_eps_fn(params: Params, cfg: DiffusionLMConfig, batch: int,
                     seq_len: int, remat: bool = False):
    """Tile-aware eps model: consumes the (R, 256) tile view directly.

    ROADMAP "Next candidates": marking the diffusion-LM eps model
    ``tile_aware = True`` deletes the last per-step eps repack from the
    tile-resident scan — and the per-tick repack from the
    continuous-batching scheduler (``slot_tile_aware``). Valid when the
    per-sample latent size ``seq_len * latent_dim`` is a multiple of the
    8x256 tile granule: then BOTH layouts (the scan's global flatten and
    the scheduler's per-slot rows) are pure reshapes of the natural
    (batch, seq_len, latent_dim) view, so the loop body traces no
    pad/slice of the state at all.

    ``t`` may be a scalar (the tile-resident scan) or a (batch,) vector
    (the scheduler: every slot at its own timestep).

    Dense-family trunks additionally get megakernel metadata (ISSUE 4):
    ``eps_fn.mega_spec`` carries the eps-path weights + static geometry so
    the 'mega' SamplerPlan backend (and the scheduler's fused tick) can
    run the WHOLE step — trunk included — inside one Pallas launch, and
    ``eps_fn.mega_vmem_bytes`` is the modeled VMEM footprint the
    eligibility rule checks against ``megastep.MEGA_VMEM_BUDGET``.
    """
    from repro.kernels.sampler_step.kernel import SUBLANE, TILE_C

    n = seq_len * cfg.latent_dim
    granule = SUBLANE * TILE_C
    if n % granule:
        raise ValueError(
            f"tile-aware diffusion-LM needs seq_len*latent_dim divisible by "
            f"{granule}, got {seq_len}*{cfg.latent_dim}={n}; use "
            f"make_eps_fn (adapter path) for unaligned shapes")
    shape = (batch, seq_len, cfg.latent_dim)

    def eps_fn(x2, t):
        t = jnp.broadcast_to(jnp.asarray(t, jnp.int32).reshape(-1), (batch,))
        e = eps_forward(params, cfg, x2.reshape(shape), t, remat=remat)
        return e.reshape(x2.shape)

    eps_fn.tile_aware = True        # tile-resident scan (core/sampler)
    eps_fn.slot_tile_aware = True   # scheduler slot layout (serving)
    if cfg.arch.family in ("dense", "vlm", "audio"):
        # the dense transformer trunk is the megakernel-capable family;
        # only the eps-path weights ride along (embed/rounding stay out)
        from repro.kernels.megastep import MegaSpec
        spec = MegaSpec(
            params={k: params[k] for k in
                    ("w_in", "time_w1", "time_w2", "layers", "out_norm",
                     "w_out")},
            cfg=cfg, batch=batch, seq_len=seq_len)
        eps_fn.mega_spec = spec
        eps_fn.mega_vmem_bytes = spec.vmem_bytes()
    return eps_fn


def embed_tokens(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Tokens -> unit-scale latents (x0 of the diffusion)."""
    e = params["embed"][tokens]
    return e / (jnp.std(e, axis=-1, keepdims=True) + 1e-6)


def round_to_tokens(params: Params, x0: jnp.ndarray) -> jnp.ndarray:
    """Latents -> tokens via the rounding head (Diffusion-LM 'rounding')."""
    return jnp.argmax(x0 @ params["rounding"], axis=-1)


def training_loss(params: Params, cfg: DiffusionLMConfig,
                  schedule: NoiseSchedule, tokens: jnp.ndarray,
                  rng: jax.Array, rounding_weight: float = 1.0,
                  remat: bool = True) -> Tuple[jnp.ndarray, Dict]:
    """L_simple on latents + rounding cross-entropy (keeps latents decodable).
    Identical in form to paper Eq. 5 — gamma = 1."""
    k_t, k_e = jax.random.split(rng)
    x0 = embed_tokens(params, tokens)
    B = tokens.shape[0]
    t = jax.random.randint(k_t, (B,), 1, schedule.T + 1)
    noise = jax.random.normal(k_e, x0.shape, dtype=x0.dtype)
    x_t = q_sample(schedule, x0, t, noise)
    eps_hat = eps_forward(params, cfg, x_t, t, remat=remat)
    l_eps = jnp.mean(jnp.square(eps_hat - noise))
    logits = x0 @ params["rounding"]
    l_round = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits, axis=-1), tokens[..., None], axis=-1))
    loss = l_eps + rounding_weight * l_round
    return loss, {"l_eps": l_eps, "l_round": l_round}


def generate(params: Params, cfg: DiffusionLMConfig, schedule: NoiseSchedule,
             rng: jax.Array, batch: int, seq_len: int,
             sampler: Optional[SamplerConfig] = None,
             tile_resident: bool = False) -> jnp.ndarray:
    """Sample token sequences with the (accelerated) DDIM process.

    ``tile_resident=True`` runs the scan in the Pallas tile layout with the
    tile-aware eps model (conversion-free loop body) when the latent size
    aligns to the tile granule, falling back to the adapter path otherwise.
    Mega-eligible trunks (dense family, VMEM-fitting — see
    ``make_tile_eps_fn``) automatically upgrade to the fused 'mega'
    backend; its own eligibility check falls back to the tile-resident
    scan bit-identically for anything else (stochastic samplers included).
    """
    sampler = sampler or SamplerConfig(S=50, eta=0.0)
    k_init, k_samp = jax.random.split(rng)
    x_T = jax.random.normal(k_init, (batch, seq_len, cfg.latent_dim))
    if tile_resident:
        try:
            eps_fn = make_tile_eps_fn(params, cfg, batch, seq_len)
        except ValueError:   # unaligned latent: adapter path still works
            eps_fn = make_eps_fn(params, cfg)
        x0 = sample(schedule, eps_fn, x_T, sampler, rng=k_samp,
                    tile_resident=True, backend="mega")
    else:
        eps_fn = make_eps_fn(params, cfg)
        x0 = sample(schedule, eps_fn, x_T, sampler, rng=k_samp)
    return round_to_tokens(params, x0)

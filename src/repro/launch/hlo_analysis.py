"""Loop-aware HLO analysis for honest roofline terms.

Why this exists: ``compiled.cost_analysis()`` counts each while-loop BODY
ONCE — but our models lax.scan over layers, so flops/bytes/collective
counts from the raw analysis are low by ~n_layers (first observed as
impossible useful-compute ratios > 1; see EXPERIMENTS.md §Roofline).

This module parses the post-SPMD HLO text structurally:
  * two passes: (1) symbol table instruction-name -> output-shape string;
    (2) per-computation tallies;
  * every ``while`` resolves body/condition; the static trip count is the
    loop-bound integer constant in the condition computation;
  * the call graph is walked from ENTRY with a MULTIPLICITY per
    computation (while bodies multiply by trip; fusions/calls inherit);
  * tallies per computation: dot flops (2 * out_elems * contracted),
    convolution flops, collective output bytes by kind, and write traffic
    (sum of instruction output bytes — a post-fusion HBM proxy).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([^\s(]+)\s*\(.*->.*\{$")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([^\s=]+)\s*=\s*(.*)$")


def _shape_elems(dims_str: str) -> int:
    if not dims_str:
        return 1
    n = 1
    for d in dims_str.split(","):
        n *= int(d)
    return n


def _shape_bytes(s: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(s):
        if dtype in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dtype]
    return total


def _out_shape_str(rhs: str) -> str:
    """Output shape portion of an instruction RHS (tuple or single)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for j, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[:j + 1]
    return rhs.split(" ", 1)[0]


def _operand_names(rhs: str, opword: str) -> List[str]:
    idx = rhs.find(opword + "(")
    if idx < 0:
        return []
    start = idx + len(opword) + 1
    depth = 0
    names, cur = [], []
    for ch in rhs[start:]:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            names.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        names.append("".join(cur).strip())
    # operands render either as "%name" or (newer jaxlib) with the shape
    # inline: "f32[64,64]{1,0} %name" — the name is the last token
    out = []
    for n in names:
        tok = n.split()[-1] if n.split() else ""
        if tok.startswith("%"):
            out.append(tok.lstrip("%"))
    return out


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    write_bytes: float = 0.0
    dot_read_bytes: float = 0.0   # operand streams of dot/conv (real reads)
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_count: int = 0
    calls: List[str] = dataclasses.field(default_factory=list)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_int_const: int = 1
    root_op: str = ""
    root_out_elems: int = 0
    dus_out_elems: int = 0          # largest DUS output in this computation
    dus_update_bytes: float = 0.0   # its update operand bytes
    pending_fusions: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list)   # (called comp, fusion output bytes)


def parse_hlo(text: str):
    lines = [l.strip() for l in text.splitlines()]

    # ---- pass 1: symbol table (instruction name -> output shape string)
    shapes: Dict[str, str] = {}
    for line in lines:
        m = _INSTR_RE.match(line)
        if m and ("(" in m.group(2)):
            shapes[m.group(1)] = _out_shape_str(m.group(2))

    # ---- pass 2: per-computation stats
    comps: Dict[str, CompStats] = {}
    fusion_bodies = set()
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in lines:
        h = _HDR_RE.match(line)
        if h:
            cur = h.group(2)
            comps[cur] = CompStats()
            if h.group(1):
                entry = cur
            continue
        if cur is None or not line or line == "}":
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        st = comps[cur]
        rhs = m.group(2)
        out_str = _out_shape_str(rhs)
        after = rhs[len(out_str):].strip()
        opword = after.split("(", 1)[0].strip()
        out_bytes = _shape_bytes(out_str)
        is_root = line.startswith("ROOT")
        if is_root:
            st.root_op = opword
            so_root = _SHAPE_RE.search(out_str)
            if so_root:
                st.root_out_elems = _shape_elems(so_root.group(2))
        # write-traffic proxy: skip no-traffic ops (parameters, tuple
        # plumbing, aliasing bitcasts, the while's carried state); count
        # in-place dynamic-update-slice as the UPDATE operand only
        # (XLA aliases the buffer); fusions whose root is a DUS likewise
        # (resolved after all computations are parsed).
        if opword in ("parameter", "tuple", "get-tuple-element", "bitcast",
                      "while", "constant", "iota"):
            pass
        elif opword == "dynamic-update-slice":
            ops = _operand_names(rhs, opword)
            upd = _shape_bytes(shapes.get(ops[1], "")) if len(ops) >= 2 \
                else out_bytes
            st.write_bytes += upd
            so_d = _SHAPE_RE.search(out_str)
            elems = _shape_elems(so_d.group(2)) if so_d else 0
            if elems > st.dus_out_elems:
                st.dus_out_elems = elems
                st.dus_update_bytes = upd
        elif opword == "fusion":
            mm = re.search(r"calls=%?([\w.\-]+)", rhs)
            st.pending_fusions.append((mm.group(1) if mm else "", out_bytes))
        else:
            st.write_bytes += out_bytes

        for mm in re.finditer(r"constant\((\d+)\)", rhs):
            st.max_int_const = max(st.max_int_const, int(mm.group(1)))

        if opword == "dot":
            ops = _operand_names(rhs, "dot")
            for o in ops[:2]:
                st.dot_read_bytes += _shape_bytes(shapes.get(o, ""))
            mc = re.search(r"rhs_contracting_dims=\{([\d,]*)\}", rhs)
            if len(ops) >= 2 and mc is not None:
                rhs_shape = shapes.get(ops[1], "")
                sm = _SHAPE_RE.search(rhs_shape)
                if sm:
                    rdims = ([int(x) for x in mc.group(1).split(",")]
                             if mc.group(1) else [])
                    rshape = ([int(d) for d in sm.group(2).split(",")]
                              if sm.group(2) else [])
                    contracted = 1
                    for d in rdims:
                        if d < len(rshape):
                            contracted *= rshape[d]
                    out_elems = 0
                    so = _SHAPE_RE.search(out_str)
                    if so:
                        out_elems = _shape_elems(so.group(2))
                    st.dot_flops += 2.0 * out_elems * contracted
        elif opword == "convolution":
            ops = _operand_names(rhs, "convolution")
            so = _SHAPE_RE.search(out_str)
            if len(ops) >= 2 and so:
                ksh = _SHAPE_RE.search(shapes.get(ops[1], ""))
                if ksh:
                    k_elems = _shape_elems(ksh.group(2))
                    out_dims = ([int(d) for d in so.group(2).split(",")]
                                if so.group(2) else [])
                    out_elems = _shape_elems(so.group(2))
                    oc = out_dims[-1] if out_dims else 1
                    st.conv_flops += 2.0 * out_elems * k_elems / max(oc, 1)
        elif opword == "while":
            mb = re.search(r"body=%?([\w.\-]+)", rhs)
            mc = re.search(r"condition=%?([\w.\-]+)", rhs)
            if mb and mc:
                st.whiles.append((mb.group(1), mc.group(1)))
        else:
            for kind in _COLLECTIVES:
                if opword.startswith(kind):
                    st.coll_bytes[kind] += out_bytes
                    st.coll_count += 1
                    break
            for mm in re.finditer(
                    r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)", rhs):
                st.calls.append(mm.group(1))
                if opword == "fusion":
                    fusion_bodies.add(mm.group(1))

    for name in fusion_bodies & comps.keys():
        comps[name].write_bytes = 0.0   # fused internals live in registers

    # resolve fusion write traffic: a fusion whose output IS a (possibly
    # dtype-converted) dynamic-update-slice of the same logical buffer is
    # in-place — count the update slice only. bf16 legalization on the CPU
    # backend wraps the DUS in converts, so match on element count rather
    # than requiring the root op to be the DUS itself.
    for st in comps.values():
        for called, out_bytes in st.pending_fusions:
            callee = comps.get(called)
            if (callee is not None and callee.dus_out_elems > 0
                    and callee.dus_out_elems == callee.root_out_elems):
                st.write_bytes += callee.dus_update_bytes
            else:
                st.write_bytes += out_bytes

    return entry, comps


def aggregate(text: str) -> Dict:
    """Loop-corrected totals for the module (per-device numbers)."""
    entry, comps = parse_hlo(text)
    mult: Dict[str, float] = {}
    trip_log: Dict[str, int] = {}

    def visit(name: str, m: float, depth: int = 0):
        if name not in comps or depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        st = comps[name]
        for body, cond in st.whiles:
            trip = comps[cond].max_int_const if cond in comps else 1
            trip_log[body] = trip
            visit(cond, m * trip, depth + 1)
            visit(body, m * trip, depth + 1)
        for callee in st.calls:
            visit(callee, m, depth + 1)

    if entry is None and comps:
        entry = next(iter(comps))
    visit(entry, 1.0)

    tot = {"dot_flops": 0.0, "conv_flops": 0.0, "write_bytes": 0.0,
           "dot_read_bytes": 0.0,
           "coll_bytes": {k: 0.0 for k in _COLLECTIVES}, "coll_count": 0.0}
    for name, m in mult.items():
        st = comps[name]
        tot["dot_flops"] += m * st.dot_flops
        tot["conv_flops"] += m * st.conv_flops
        tot["write_bytes"] += m * st.write_bytes
        tot["dot_read_bytes"] += m * st.dot_read_bytes
        tot["coll_count"] += m * st.coll_count
        for k in _COLLECTIVES:
            tot["coll_bytes"][k] += m * st.coll_bytes[k]
    tot["flops"] = tot["dot_flops"] + tot["conv_flops"]
    tot["traffic_bytes"] = tot["write_bytes"] + tot["dot_read_bytes"]
    tot["coll_bytes_total"] = sum(tot["coll_bytes"].values())
    tot["trip_counts"] = trip_log
    return tot

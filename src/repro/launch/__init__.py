"""Launch layer: production mesh, input shapes, dry-run, train/serve CLIs.

NOTE: import repro.launch.dryrun only as __main__ (it sets XLA_FLAGS for 512
placeholder devices before jax init). mesh/shapes/roofline are import-safe.
"""
from . import mesh, roofline, shapes

"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

cost_analysis() on the partitioned module reports PER-DEVICE flops/bytes,
so the per-chip terms divide by peak only. collective_bytes is parsed from
the post-SPMD HLO text: we sum the OUTPUT buffer sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
(a per-device byte count, since the partitioned HLO is the per-device
program).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI
per link.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional, Tuple

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string (handles tuples by summing parts)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-buffer bytes per collective kind from post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", stripped)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
        out["count"] += 1
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops
    bytes_accessed: float        # per-device HBM bytes
    coll_bytes: float            # per-device collective bytes
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None    # 6*N*D (global, useful flops)
    useful_ratio: Optional[float] = None   # model_flops / global HLO flops

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(compiled, hlo_text: str, n_chips: int,
            model_flops: Optional[float] = None,
            links_per_chip: float = 1.0) -> RooflineTerms:
    """Loop-corrected roofline terms.

    Uses hlo_analysis.aggregate (walks the call graph with while-loop trip
    multiplicities) because raw cost_analysis counts lax.scan bodies ONCE,
    undercounting layered models by ~n_layers (EXPERIMENTS.md §Roofline).
    """
    from .hlo_analysis import aggregate
    tot = aggregate(hlo_text)
    flops = float(tot["flops"])
    byts = float(tot["traffic_bytes"])
    cbytes = float(tot["coll_bytes_total"])
    coll = {k: int(v) for k, v in tot["coll_bytes"].items()}
    coll["count"] = int(tot["coll_count"])
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    collective_s = cbytes / (ICI_BW * links_per_chip)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / max(flops * n_chips, 1.0)
    return RooflineTerms(flops=flops, bytes_accessed=byts, coll_bytes=cbytes,
                         coll_breakdown=coll, compute_s=compute_s,
                         memory_s=memory_s, collective_s=collective_s,
                         bottleneck=bottleneck, model_flops=model_flops,
                         useful_ratio=useful)


def lm_model_flops(n_params_active: int, n_tokens: int,
                   kind: str = "train") -> float:
    """6*N*D for training; 2*N*D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * n_tokens


def memory_report(compiled) -> Dict:
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {}
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        return {k: int(getattr(m, k)) for k in keys if hasattr(m, k)}
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}

"""Training driver.

Two modes:
  * --arch <id>        LM pretraining on the synthetic Markov-chain corpus
                       (reduced --smoke configs run on CPU).
  * --arch unet        The paper's own training: U-Net eps-model on the
                       synthetic image distribution with L_simple (Eq. 5,
                       gamma=1), EMA tracking, checkpoints.

Example (CPU, used by EXPERIMENTS.md):
  PYTHONPATH=src python -m repro.launch.train --arch unet --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import make_schedule, training_loss
from repro.data import SyntheticImages, SyntheticTokens
from repro.models import get_api, unet
from repro.training import (AdamWConfig, ema_init, ema_update,
                            init_train_state, make_diffusion_train_step,
                            make_lm_train_step, warmup_cosine, checkpoint)


def train_unet(args):
    ucfg = configs.TOY_UNET if args.smoke or True else configs.CIFAR10_UNET
    schedule = make_schedule("linear", T=args.T)
    params = unet.init_params(jax.random.PRNGKey(args.seed), ucfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"U-Net params: {n_params/1e6:.2f}M  T={args.T}")

    def loss_fn(params, batch, rng):
        eps_fn = lambda x, t: unet.forward(params, ucfg, x, t)
        loss = training_loss(schedule, eps_fn, batch, rng)
        return loss, {}

    opt_cfg = AdamWConfig(lr=args.lr,
                          schedule=warmup_cosine(100, args.steps))
    step_fn = jax.jit(make_diffusion_train_step(loss_fn, opt_cfg))
    state = init_train_state(params, jax.random.PRNGKey(args.seed + 1),
                             opt_cfg)
    ema = ema_init(params)
    data = SyntheticImages(size=args.image_size, seed=args.seed)
    gen = data.batches(args.batch)

    t0 = time.time()
    for step in range(1, args.steps + 1):
        state, metrics = step_fn(state, next(gen))
        ema = ema_update(ema, state.params, decay=0.999)
        if step % args.log_every == 0 or step == 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/step:.2f}s/step)", flush=True)
        if args.ckpt_dir and step % args.ckpt_every == 0:
            checkpoint.save_step(args.ckpt_dir, step,
                                 {"params": state.params, "ema": ema})
    if args.ckpt_dir:
        path = checkpoint.save_step(args.ckpt_dir, args.steps,
                                    {"params": state.params, "ema": ema})
        print(f"final checkpoint: {path}")
    return state, ema


def train_lm(args):
    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    api = get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.2f}M params")
    opt_cfg = AdamWConfig(lr=args.lr,
                          schedule=warmup_cosine(20, args.steps))
    step_fn = jax.jit(make_lm_train_step(cfg, opt_cfg))
    state = init_train_state(params, jax.random.PRNGKey(args.seed + 1),
                             opt_cfg)
    data = SyntheticTokens(vocab=cfg.vocab, seed=args.seed)
    gen = data.batches(args.batch, args.seq)

    embeds = None
    if cfg.family in ("vlm", "audio"):
        embeds = jax.random.normal(jax.random.PRNGKey(9),
                                   (args.batch, cfg.n_ctx_embeds,
                                    cfg.d_model)) * 0.02
    t0 = time.time()
    losses = []
    for step in range(1, args.steps + 1):
        batch = {"tokens": next(gen)}
        if embeds is not None:
            batch["embeds"] = embeds
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == 1:
            print(f"step {step:5d} loss={losses[-1]:.4f} "
                  f"({(time.time()-t0)/step:.2f}s/step)", flush=True)
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1]}))
    if args.ckpt_dir:
        checkpoint.save_step(args.ckpt_dir, args.steps,
                             {"params": state.params})
    return state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="'unet' or one of " + ", ".join(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--T", type=int, default=1000)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=200)
    args = ap.parse_args()
    if args.arch == "unet":
        train_unet(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()

"""Serving driver: batched AR generation over any assigned architecture
(reduced configs on CPU), or DDIM sampling from a U-Net checkpoint — in
lockstep batches or through the continuous-batching scheduler.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 4 --new-tokens 16
  PYTHONPATH=src python -m repro.launch.serve --arch unet \
      --ckpt results/unet/ckpt_00000300.npz --S 20 --eta 0.0
  PYTHONPATH=src python -m repro.launch.serve --arch unet --scheduler \
      --slots 4 --s-mix 10,20,50 --n-samples 12
  PYTHONPATH=src python -m repro.launch.serve --arch unet --gateway \
      --port 8807       # async HTTP/SSE front door (docs/gateway.md)

``--gateway`` serves the U-Net fleet behind the async front door
(serving/gateway): POST /v1/sample with ``"stream": true`` streams x0
previews + the terminal result over SSE, /v1/models lists the routable
models, and POST /v1/models/{name}/rollout hot-swaps staged weights
without dropping in-flight work. ``--gateway --smoke`` round-trips a
live client and exits (the tier-1 launch-path guard).

``--scheduler`` serves a mixed-step-budget request stream through
serving/scheduler: each request samples at its OWN S (--s-mix cycles),
slots refill mid-flight, and per-request latency is reported alongside
engine occupancy/throughput stats (docs/serving.md). Telemetry flags
(docs/observability.md): ``--dash`` live per-pool dashboard,
``--trace-out`` per-request JSONL spans, ``--prom-out`` Prometheus
snapshot, ``--profile`` jax.profiler tick annotations; every replay ends
with a p50/p95/p99 latency + miss/drop summary table.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import make_schedule
from repro.models import get_api, unet
from repro.obs import (JsonlSink, Observability, render_dashboard,
                       render_summary, summarize_results)
from repro.sampling import SamplerPlan, SigmaSpec, TauSpec
from repro.serving import (ARGenerator, DiffusionSampler, GenRequest,
                           SampleRequest)
from repro.training import checkpoint


def _make_obs(args) -> tuple:
    """The CLI's telemetry handle + the JSONL trace path (or None)."""
    obs = Observability(profile=args.profile)
    trace_path = args.trace_out or None
    if trace_path:
        obs.add_sink(JsonlSink(trace_path))
    return obs, trace_path


def _drain(server, dash: bool, every: int = 25):
    """Drain a scheduler engine or fleet, optionally live-dashboarding.

    ``server`` is anything with tick()/stats() and a busy predicate
    (PoolFleet has ``.busy``; the engine is busy while queued + resident
    work remains). With ``dash`` the per-pool table re-renders every
    ``every`` ticks and once at exit.
    """
    busy = ((lambda: server.busy) if hasattr(server, "busy")
            else (lambda: len(server.queue) > 0 or server.active > 0))
    results = []
    n = 0
    while busy():
        results.extend(server.tick())
        n += 1
        if dash and n % every == 0:
            print(render_dashboard(server.stats()))
    if dash:
        print(render_dashboard(server.stats()))
    return results


def _finish_replay(results, server, obs, trace_path, args) -> None:
    """Replay exit: summary table (+ dashboard), flush trace, exporters."""
    if not args.dash:               # --dash already rendered the table
        print(render_dashboard(server.stats()))
    obs.close()                     # flush + close the JSONL sink
    print(render_summary(summarize_results(results), trace_path))
    if args.prom_out:
        render = getattr(server, "render_prometheus", None)
        text = (render() if render is not None
                else server.obs.render_prometheus())
        with open(args.prom_out, "w") as f:
            f.write(text)
        print(f"metrics    {args.prom_out}")
    if getattr(args, "flight_dir", None):
        # replay postmortems on request: dump every recorder's ring so a
        # clean run's trajectory-quality history is inspectable too
        engines = ([p.engine for p in server.pools]
                   if hasattr(server, "pools") else [server])
        for eng in engines:
            flight = getattr(eng, "flight", None)
            if flight is not None:
                path = flight.dump("replay-end")
                if path is not None:
                    print(f"flight     {path}")
    if args.out:
        done = [r for r in sorted(results, key=lambda r: r.request_id)
                if r.x0 is not None]
        np.save(args.out, np.stack([r.x0 for r in done]))
        print(f"saved -> {args.out}")


def serve_lm(args):
    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    api = get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        ref = {"params": params}
        restored, _ = checkpoint.restore(args.ckpt, ref)
        params = restored["params"]
    embeds = None
    if cfg.family in ("vlm", "audio"):
        embeds = jax.random.normal(jax.random.PRNGKey(9),
                                   (args.batch, cfg.n_ctx_embeds,
                                    cfg.d_model)) * 0.02
    gen = ARGenerator(cfg, params, batch_size=args.batch,
                      max_len=args.prompt_len + args.new_tokens +
                      (cfg.n_ctx_embeds if cfg.family == "vlm" else 0))
    rng = np.random.RandomState(args.seed)
    reqs = [GenRequest(prompt=rng.randint(0, cfg.vocab, args.prompt_len)
                       .astype(np.int32),
                       max_new_tokens=args.new_tokens,
                       temperature=args.temperature)
            for _ in range(args.batch)]
    results = gen.generate(reqs, embeds=embeds)
    for i, r in enumerate(results):
        print(f"req{i}: {r.tokens[:16]}...")
    print(f"prefill={results[0].prefill_ms:.1f}ms "
          f"decode={results[0].decode_ms:.1f}ms "
          f"throughput={results[0].tokens_per_s:.1f} tok/s")


def serve_unet_gateway(args):
    """--gateway: serve the U-Net through the async HTTP/SSE front door.

    Builds a multi-model GatewayCore (serving/gateway) over slot pools:
    with --ckpt the checkpoint's 'ema' and 'raw' weight sets become two
    routable models (same trunk, hot-swap-compatible); without one, two
    differently-seeded inits stand in ('base'/'alt'). Serves on --port
    until Ctrl-C. --smoke binds an ephemeral port, round-trips one JSON
    and one streaming SSE request per model through a live aiohttp
    client, prints a one-line verdict, and exits non-zero on failure —
    the tier-1 guard that this launch path can't rot.
    """
    import asyncio

    from repro.serving.gateway import HAVE_HTTP
    if not HAVE_HTTP:
        raise SystemExit("--gateway requires aiohttp for the HTTP/SSE "
                         "transport (serving/gateway/http.py)")
    from repro.serving.gateway import (GatewayCore, OverloadPolicy,
                                       start_gateway, stop_gateway)

    ucfg = configs.TOY_UNET
    schedule = make_schedule("linear", T=args.T)
    base = unet.init_params(jax.random.PRNGKey(args.seed), ucfg)
    if args.ckpt:
        ref = {"params": base, "ema": base}
        restored, _ = checkpoint.restore(args.ckpt, ref)
        models = {"ema": restored["ema"], "raw": restored["params"]}
    else:
        models = {"base": base,
                  "alt": unet.init_params(jax.random.PRNGKey(args.seed + 1),
                                          ucfg)}
    obs, _ = _make_obs(args)
    core = GatewayCore.build(
        schedule, lambda p, x, t: unet.forward(p, ucfg, x, t),
        (args.image_size, args.image_size, 3),
        models=models, pools_per_model=max(1, args.pools),
        slots=args.slots, policy=OverloadPolicy(), obs=obs,
        probes=args.probes or None, flight_dir=args.flight_dir)

    async def _smoke_client(port: int) -> bool:
        import aiohttp
        url = f"http://127.0.0.1:{port}"
        async with aiohttp.ClientSession() as sess:
            async with sess.get(f"{url}/v1/models") as r:
                names = sorted(await r.json())
            # JSON round-trip on one model, SSE previews on the other
            spec = {"model": names[0], "S": 4, "seed": args.seed}
            async with sess.post(f"{url}/v1/sample", json=spec) as r:
                body = await r.json()
                ok = r.status == 200 and body["event"] == "result"
            spec = {"model": names[-1], "S": 6, "seed": args.seed + 1,
                    "stream": True, "preview_every": 2}
            previews = results = 0
            async with sess.post(f"{url}/v1/sample", json=spec) as r:
                async for raw in r.content:
                    line = raw.decode("utf-8").strip()
                    if line == "event: preview":
                        previews += 1
                    elif line == "event: result":
                        results += 1
            ok = ok and results == 1 and previews > 0
            async with sess.get(f"{url}/v1/stats") as r:
                st = await r.json()
        print(f"gateway smoke: models={names} json+sse round-trips "
              f"previews={previews} requests={st['requests']} "
              f"({'OK' if ok else 'FAIL'})")
        return ok

    async def _serve() -> int:
        runner, bridge, port = await start_gateway(
            core, port=0 if args.smoke else args.port)
        if args.smoke:
            ok = await _smoke_client(port)
            await stop_gateway(runner, bridge)
            return 0 if ok else 1
        print(f"gateway listening on http://127.0.0.1:{port} "
              f"(models: {sorted(models)}; Ctrl-C to stop)")
        try:
            await asyncio.Event().wait()
        finally:
            await stop_gateway(runner, bridge)
        return 0

    try:
        rc = asyncio.run(_serve())
    except KeyboardInterrupt:
        rc = 0
    if rc:
        raise SystemExit(rc)


def serve_unet(args):
    if args.gateway:
        return serve_unet_gateway(args)
    ucfg = configs.TOY_UNET
    schedule = make_schedule("linear", T=args.T)
    params = unet.init_params(jax.random.PRNGKey(args.seed), ucfg)
    if args.ckpt:
        ref = {"params": params, "ema": params}
        restored, _ = checkpoint.restore(args.ckpt, ref)
        params = restored["ema"]            # sample from the EMA model
    eps_fn = unet.make_eps_fn(params, ucfg)
    bank = None
    if args.plan_bank:
        from repro.autoplan import PlanBank
        bank = PlanBank.load(args.plan_bank, schedule)
        print(f"plan bank: {len(bank)} rows, NFE frontier {bank.nfes}")
    svc = DiffusionSampler(schedule, eps_fn,
                           (args.image_size, args.image_size, 3),
                           batch_size=args.batch, plan_bank=bank)
    if args.scheduler:
        return serve_unet_continuous(args, svc)
    if bank is not None:
        # budget-bounded bank row: the best searched trajectory <= --S NFE
        plan = svc.bank_plan(max_nfe=args.S)
        if plan.S > args.S:
            # bank_plan falls back to the smallest row when nothing fits
            print(f"warning: no bank row fits --S {args.S}; serving the "
                  f"smallest searched row (S={plan.S})")
    else:
        plan = SamplerPlan.build(
            schedule, tau=(TauSpec.quadratic(args.S)
                           if args.tau == "quadratic"
                           else TauSpec.uniform(args.S)),
            sigma=args.eta, order=args.order)
    samples, stats = svc.serve(args.n_samples, plan, seed=args.seed)
    print(f"sampled {samples.shape} in {stats['batches']} batches; "
          f"steady={stats['steady_batch_s']:.2f}s/batch "
          f"({stats['samples_per_s']:.2f} samples/s, {plan})")
    if args.out:
        np.save(args.out, np.asarray(samples))
        print(f"saved -> {args.out}")


def serve_unet_continuous(args, svc: DiffusionSampler):
    """Mixed-PLAN request stream through the continuous-batching scheduler.

    Each request carries its own frozen SamplerPlan: the S mix cycles,
    tau spacing alternates uniform/quadratic, and (with --order > 1) every
    third request upgrades to the multistep solver — all multiplexed
    through ONE compiled tick.
    """
    s_mix = [int(s) for s in args.s_mix.split(",")]
    stochastic = args.eta > 0.0
    max_order = args.order
    clip_x0 = None
    if svc.plan_bank is not None:
        # size the engine to the whole bank frontier: refined rows may be
        # stochastic (eta schedules), multistep, or clipped, and an engine
        # only serves bank rows within its own caps
        bank = svc.plan_bank
        stochastic = stochastic or any(bank.plan(n).stochastic
                                       for n in bank.nfes)
        max_order = max([max_order] + [e.order for e in bank.entries])
        clips = [e.clip for e in bank.entries]
        uniq = set(clips)
        if len(uniq) == 1:
            clip_x0 = uniq.pop()
        elif len(uniq) > 1:
            # an engine compiles ONE clip; serve the biggest bank subset
            clip_x0 = max(uniq, key=clips.count)
            print(f"warning: bank mixes clip values "
                  f"{sorted(map(str, uniq))}; engine serves only its "
                  f"clip_x0={clip_x0} rows")
    schedule = svc.schedule
    if args.pools > 1:
        return serve_unet_fleet(args, svc, stochastic=stochastic,
                                max_order=max_order, clip_x0=clip_x0)
    obs, trace_path = _make_obs(args)
    flight = None
    if args.probes:
        from repro.obs import FlightRecorder
        flight = FlightRecorder(pool_id=0, out_dir=args.flight_dir)
    eng = svc.continuous(slots=args.slots, stochastic=stochastic,
                         max_order=max_order, clip_x0=clip_x0, obs=obs,
                         probes=args.probes or None, flight=flight)

    def plan_for(i: int) -> SamplerPlan:
        S = s_mix[i % len(s_mix)]
        tau = (TauSpec.quadratic(S) if (args.tau == "quadratic"
                                        or (args.tau == "mix" and i % 2))
               else TauSpec.uniform(S))
        order = args.order if (args.order > 1 and i % 3 == 0
                               and args.eta == 0.0) else 1
        return SamplerPlan.build(schedule, tau=tau,
                                 sigma=SigmaSpec.from_eta(args.eta),
                                 order=order)

    deadlines = [float(d) for d in args.deadlines.split(",")] \
        if args.deadlines else [None]
    import time as _time

    # warm the tick before stamping any deadline: the one-off XLA trace
    # (seconds on CPU) must neither eat the requests' headroom nor — on
    # the bank path — poison the EWMA the selection policy consults
    if svc.plan_bank is not None:
        eng.submit(SampleRequest(request_id=-1, auto_plan=True, seed=0))
        eng.run()
        eng.reset_stats()        # keep the compiled tick + measured EWMA
    elif args.deadlines:
        eng.submit(SampleRequest(request_id=-1, plan=plan_for(0), seed=0))
        eng.run()
        eng.reset_stats()
    now = _time.perf_counter()

    def deadline_for(i: int):
        d = deadlines[i % len(deadlines)]
        return None if d is None else now + d

    if svc.plan_bank is not None:
        # deadline-aware bank selection: every request lets the ENGINE
        # pick its plan at admission; the cycled relative deadlines make
        # the policy choose different NFE rows across one trace
        reqs = [SampleRequest(request_id=i, auto_plan=True,
                              deadline=deadline_for(i), seed=args.seed + i)
                for i in range(args.n_samples)]
    else:
        reqs = [SampleRequest(request_id=i, plan=plan_for(i),
                              deadline=deadline_for(i), seed=args.seed + i)
                for i in range(args.n_samples)]
    if args.dash:
        for r in reqs:
            eng.submit(r)
        results = _drain(eng, dash=True)
    else:
        results = eng.serve(reqs)
    by_id = {r.request_id: r for r in results}
    for i in sorted(by_id):
        r = by_id[i]
        sel = (f" nfe={r.nfe} headroom="
               + (f"{r.deadline_headroom_s*1e3:.0f}ms"
                  if r.deadline_headroom_s is not None else "inf")
               if r.auto_plan else "")
        print(f"req{r.request_id}: {reqs[i].plan} "
              f"wait={r.queue_wait_s*1e3:.1f}ms "
              f"service={r.service_s*1e3:.1f}ms "
              f"latency={r.latency_s*1e3:.1f}ms{sel}")
    _finish_replay(results, eng, obs, trace_path, args)


def serve_unet_fleet(args, svc: DiffusionSampler, *, stochastic,
                     max_order, clip_x0):
    """--pools N: the mixed-S stream through a slot-pool fleet.

    N continuous-batching pools behind the global EDF queue with
    least-loaded dispatch (serving/fleet). When the local device count
    divides evenly, each pool runs on its own disjoint mesh slice
    (launch.mesh.make_fleet_mesh) — force host devices with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 to see sharded
    pools on CPU. Requests cycle an affinity key to exercise sticky
    routing; per-pool stats print at the end.
    """
    from repro.serving.fleet import PoolFleet

    s_mix = [int(s) for s in args.s_mix.split(",")]
    meshes = None
    n_dev = len(jax.devices())
    if n_dev >= 2 * args.pools and n_dev % args.pools == 0:
        from repro.launch.mesh import make_fleet_mesh
        meshes = make_fleet_mesh(args.pools)
    obs, trace_path = _make_obs(args)
    fleet = PoolFleet.build(
        svc.schedule, svc.eps_fn,
        (args.image_size, args.image_size, 3), n_pools=args.pools,
        slots=args.slots, meshes=meshes, dtype=svc.dtype,
        stochastic=stochastic, max_order=max_order, clip_x0=clip_x0,
        plan_bank=svc.plan_bank, obs=obs,
        probes=args.probes or None, flight_dir=args.flight_dir)
    # warm every pool's tick before stamping latencies
    fleet.serve([SampleRequest(request_id=-1 - p, S=min(s_mix), seed=0)
                 for p in range(args.pools)], now=0.0)
    fleet.reset_stats()
    reqs = [SampleRequest(request_id=i, S=s_mix[i % len(s_mix)],
                          eta=args.eta, seed=args.seed + i,
                          affinity_key=i % (2 * args.pools))
            for i in range(args.n_samples)]
    if args.dash:
        for r in reqs:
            fleet.submit(r)
        results = _drain(fleet, dash=True)
    else:
        results = fleet.serve(reqs)
    for r in sorted(results, key=lambda r: r.request_id):
        print(f"req{r.request_id}: S={r.S} pool={r.pool_id} "
              f"wait={r.queue_wait_s*1e3:.1f}ms "
              f"latency={r.latency_s*1e3:.1f}ms")
    _finish_replay(results, fleet, obs, trace_path, args)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--n-samples", type=int, default=8)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--T", type=int, default=1000)
    ap.add_argument("--S", type=int, default=20)
    ap.add_argument("--eta", type=float, default=0.0)
    ap.add_argument("--tau", choices=["uniform", "quadratic", "mix"],
                    default="uniform",
                    help="tau spacing; 'mix' alternates per request "
                    "(--scheduler)")
    ap.add_argument("--order", type=int, default=1,
                    help="Adams-Bashforth solver order (1..4); with "
                    "--scheduler every 3rd request upgrades to it")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve through the continuous-batching scheduler")
    ap.add_argument("--gateway", action="store_true",
                    help="unet: serve through the async HTTP/SSE gateway "
                    "(serving/gateway) instead of a local replay; with "
                    "--smoke, round-trip a live client and exit")
    ap.add_argument("--port", type=int, default=8807,
                    help="--gateway: TCP port to bind (--smoke always "
                    "uses an ephemeral port)")
    ap.add_argument("--slots", type=int, default=4,
                    help="resident scheduler slots (--scheduler; per pool "
                    "with --pools)")
    ap.add_argument("--pools", type=int, default=1,
                    help="with --scheduler: serve through a fleet of N "
                    "slot pools (global EDF queue + least-loaded/affinity "
                    "routing; disjoint pool meshes when the device count "
                    "divides)")
    ap.add_argument("--s-mix", default="10,20,50",
                    help="comma list of per-request step budgets to cycle")
    ap.add_argument("--plan-bank", default=None,
                    help="PlanBank JSON (repro.autoplan): lockstep serves "
                    "the best bank row <= --S; --scheduler switches every "
                    "request to deadline-aware bank selection")
    ap.add_argument("--deadlines", default="",
                    help="comma list of relative deadlines in seconds to "
                    "cycle across --scheduler requests (with --plan-bank: "
                    "drives the per-request NFE selection)")
    ap.add_argument("--dash", action="store_true",
                    help="with --scheduler: live per-pool console "
                    "dashboard re-rendered during the replay")
    ap.add_argument("--trace-out", default=None,
                    help="with --scheduler: write per-request trace spans "
                    "(structured JSONL, repro.obs) to this path")
    ap.add_argument("--prom-out", default=None,
                    help="with --scheduler: write a Prometheus text "
                    "metrics snapshot at replay exit")
    ap.add_argument("--probes", action="store_true",
                    help="enable the device-probe tier (obs/probes.py): "
                         "per-slot eps/x0/finite/defect reductions fused "
                         "into the tick, quality columns in --dash, and "
                         "per-request quality summaries")
    ap.add_argument("--flight-dir", default=None,
                    help="directory for flight-recorder JSONL postmortems "
                         "(implies an in-memory ring even when faults "
                         "never fire; requires --probes)")
    ap.add_argument("--profile", action="store_true",
                    help="with --scheduler: wrap ticks in jax.profiler "
                    "trace annotations (repro/tick/<variant>) so a "
                    "device profile attributes time per tick variant")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.gateway and args.arch != "unet":
        ap.error("--gateway serves the diffusion fleet; use --arch unet")
    if args.order > 1 and args.eta > 0.0 and not args.scheduler:
        # multistep integrates the deterministic ODE view; the scheduler
        # path downgrades per request, the lockstep path must reject
        ap.error("--order > 1 requires --eta 0 (multistep plans are "
                 "deterministic); drop --order or use --eta 0")
    if args.arch == "unet":
        serve_unet(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()

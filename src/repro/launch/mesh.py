"""Production mesh construction (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis for cross-pod data parallelism (DCN-attached)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (CPU smoke / examples).

    Works on the forced-multi-device CPU path too: run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and the N
    simulated host devices form the ("data", "model") mesh.
    """
    n = len(jax.devices())
    if model < 1 or n % model:
        raise ValueError(
            f"local device count {n} is not divisible by model={model}; "
            f"pick a model-axis size that divides {n} (e.g. force more "
            "host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<k*model>)")
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_fleet_mesh(n_pools: int, model: int = 1):
    """Split the local devices into ``n_pools`` disjoint pool meshes.

    Each pool gets its own ("data", "model") mesh over a contiguous,
    non-overlapping slice of ``jax.devices()`` — the device-level view of
    a data-parallel slot-pool fleet (serving/fleet): tensor/data sharding
    INSIDE a pool, pure data parallelism ACROSS pools. Returns a list of
    ``n_pools`` meshes. CPU simulation recipe: force 8 host devices and
    ``make_fleet_mesh(2, model=2)`` yields two (2, 2) pool meshes.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = len(devs)
    if n_pools < 1 or n % n_pools:
        raise ValueError(
            f"local device count {n} is not divisible by n_pools="
            f"{n_pools}; pick a pool count that divides {n} (e.g. force "
            "more host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"<k*{n_pools}>)")
    per = n // n_pools
    if model < 1 or per % model:
        raise ValueError(
            f"per-pool device count {per} (= {n} devices / {n_pools} "
            f"pools) is not divisible by model={model}")
    return [Mesh(np.asarray(devs[p * per:(p + 1) * per])
                 .reshape(per // model, model), ("data", "model"))
            for p in range(n_pools)]


# Hardware constants for the roofline analysis (TPU v5e)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link

"""Production mesh construction (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis for cross-pod data parallelism (DCN-attached)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (CPU smoke / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# Hardware constants for the roofline analysis (TPU v5e)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link

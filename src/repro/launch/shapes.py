"""Assigned input shapes and per-(arch x shape) ShapeDtypeStruct stand-ins.

  train_4k     seq_len=4096    global_batch=256   (training)
  prefill_32k  seq_len=32768   global_batch=32    (inference-prefill)
  decode_32k   seq_len=32768   global_batch=128   (inference-decode)
  long_500k    seq_len=524288  global_batch=1     (long-context-decode)

Decode shapes lower ``decode_step`` (ONE token against a seq_len cache).
long_500k policy (DESIGN.md §4): native for ssm/hybrid; dense/moe/vlm/audio
run a sliding-window (8192) variant — marked via ``windowed`` in the combo.

For stub-frontend archs: vlm gets (B, n_ctx_embeds, d) patch embeddings and
text length seq_len - n_ctx_embeds (total positions == seq_len); audio
splits the budget between encoder frames and decoder text for train/prefill
and uses the decoder cache for decode shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

SDS = jax.ShapeDtypeStruct

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

SHAPE_IDS = list(SHAPES)
WINDOW = 8192  # sliding-window size for the long_500k dense variant


@dataclasses.dataclass(frozen=True)
class Combo:
    """One (architecture x input shape) dry-run combination."""
    arch: ArchConfig
    shape_id: str
    kind: str            # train | prefill | decode
    batch: int
    seq_len: int
    windowed: bool       # sliding-window long_500k variant


def resolve(cfg: ArchConfig, shape_id: str) -> Combo:
    s = SHAPES[shape_id]
    windowed = False
    if shape_id == "long_500k" and cfg.family not in ("ssm",):
        # hybrid keeps full shared-attn KV (9 apps, sub-quadratic overall);
        # every full-attention family gets the window variant.
        if cfg.family != "hybrid":
            cfg = dataclasses.replace(cfg, sliding_window=WINDOW)
            windowed = True
    return Combo(arch=cfg, shape_id=shape_id, kind=s["kind"],
                 batch=s["global_batch"], seq_len=s["seq_len"],
                 windowed=windowed)


def _embeds_spec(cfg: ArchConfig, batch: int, n: int, dtype) -> SDS:
    return SDS((batch, n, cfg.d_model), dtype)


def input_specs(combo: Combo, dtype=jnp.bfloat16) -> Dict[str, SDS]:
    """ShapeDtypeStruct stand-ins for every model input of this combo
    (weak-type-correct, shardable, zero allocation)."""
    cfg, B, L = combo.arch, combo.batch, combo.seq_len
    if combo.kind == "train":
        if cfg.family == "vlm":
            n_img = cfg.n_ctx_embeds
            return {"tokens": SDS((B, L - n_img), jnp.int32),
                    "embeds": _embeds_spec(cfg, B, n_img, dtype)}
        if cfg.family == "audio":
            return {"tokens": SDS((B, L // 2), jnp.int32),
                    "embeds": _embeds_spec(cfg, B, L // 2, dtype)}
        return {"tokens": SDS((B, L), jnp.int32)}
    if combo.kind == "prefill":
        if cfg.family == "vlm":
            n_img = cfg.n_ctx_embeds
            return {"tokens": SDS((B, L - n_img), jnp.int32),
                    "embeds": _embeds_spec(cfg, B, n_img, dtype)}
        if cfg.family == "audio":
            # encoder takes the 32k frames; decoder prompt is short
            return {"tokens": SDS((B, 256), jnp.int32),
                    "embeds": _embeds_spec(cfg, B, L, dtype)}
        return {"tokens": SDS((B, L), jnp.int32)}
    # decode: one new token
    return {"tokens": SDS((B, 1), jnp.int32)}


def cache_specs(combo: Combo, dtype=jnp.bfloat16):
    """Abstract cache pytree for prefill/decode combos."""
    from repro.models import get_api
    cfg = combo.arch
    api = get_api(cfg)
    if cfg.family == "audio" and combo.kind == "prefill":
        # cross cache must match the encoder frame count of this combo
        import functools
        from repro.models import encdec
        return jax.eval_shape(functools.partial(
            encdec.init_cache, cfg, combo.batch, 256 + 64, combo.seq_len,
            dtype))
    import functools
    return jax.eval_shape(functools.partial(
        api.init_cache, cfg, combo.batch, combo.seq_len, dtype=dtype))

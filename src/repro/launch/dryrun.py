import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and dump roofline terms.

This proves the distribution config is coherent without real hardware: 512
placeholder host devices let GSPMD partition the exact production programs;
sharding mismatches, compile-time OOMs, or unsupported collectives fail here.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import functools
import gc
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (analyze, lm_model_flops, memory_report)
from repro.models import get_api
from repro.models.common import ArchConfig
from repro.sharding import (replicated, shard_batch, shard_cache,
                            shard_params)
from repro.training import (AdafactorConfig, AdamWConfig, TrainState,
                            init_train_state, make_decode_step,
                            make_lm_train_step, make_prefill_step)
from repro.training.optim import adafactor_init, adamw_init

ADAFACTOR_THRESHOLD = 50e9  # params; above this, train uses Adafactor


def _count(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def active_params(param_shapes, cfg: ArchConfig) -> int:
    """Active parameter count (MoE: top_k of n_experts routed)."""
    total, expert = 0, 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if "/moe/" in pstr and not pstr.split("/")[-1].startswith("sw"):
            if pstr.split("/")[-1] != "router":
                expert += n
    if cfg.n_experts:
        return total - expert + int(expert * cfg.top_k / cfg.n_experts)
    return total


def build_abstract(combo: shp.Combo, mesh, dtype=jnp.bfloat16):
    """Abstract (ShapeDtypeStruct) args + shardings for this combo."""
    cfg = combo.arch
    api = get_api(cfg)
    param_shapes = jax.eval_shape(
        functools.partial(api.init_params, cfg=cfg, dtype=dtype),
        jax.random.PRNGKey(0))
    p_shard = shard_params(param_shapes, mesh)
    inputs = shp.input_specs(combo, dtype)
    in_shard = shard_batch(inputs, mesh)
    return param_shapes, p_shard, inputs, in_shard


def lower_train(combo: shp.Combo, mesh):
    cfg = combo.arch
    dtype = jnp.bfloat16
    param_shapes, p_shard, inputs, in_shard = build_abstract(combo, mesh,
                                                             dtype)
    n_params = _count(param_shapes)
    if n_params > ADAFACTOR_THRESHOLD:
        opt_cfg = AdafactorConfig()
        opt_init = adafactor_init
    else:
        opt_cfg = AdamWConfig()
        opt_init = adamw_init
    opt_shapes = jax.eval_shape(opt_init, param_shapes)
    opt_shard = shard_params(opt_shapes, mesh)
    rng_shape = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    state_shapes = TrainState(param_shapes, opt_shapes, rng_shape)
    state_shard = TrainState(p_shard, opt_shard, replicated(mesh))
    metrics_shard = {k: replicated(mesh) for k in
                     ("loss", "aux", "grad_norm", "lr")}
    if isinstance(opt_cfg, AdafactorConfig):
        metrics_shard = {k: replicated(mesh) for k in
                         ("loss", "aux", "grad_norm")}
    from repro.models.runtime_flags import FLAGS as _PF
    train_step = make_lm_train_step(cfg, opt_cfg,
                                    accum_steps=_PF.accum_steps)
    jitted = jax.jit(train_step,
                     in_shardings=(state_shard, in_shard),
                     out_shardings=(state_shard, metrics_shard))
    with mesh:
        lowered = jitted.lower(state_shapes, inputs)
    return lowered, n_params, active_params(param_shapes, cfg)


def lower_prefill(combo: shp.Combo, mesh):
    cfg = combo.arch
    dtype = jnp.bfloat16
    param_shapes, p_shard, inputs, in_shard = build_abstract(combo, mesh,
                                                             dtype)
    cache_shapes = shp.cache_specs(combo, dtype)
    c_shard = shard_cache(cache_shapes, mesh, combo.batch)
    step = make_prefill_step(cfg)
    jitted = jax.jit(
        step,
        in_shardings=(p_shard, in_shard["tokens"], c_shard,
                      in_shard.get("embeds")),
        out_shardings=(replicated(mesh)
                       if combo.batch % mesh.devices.size else None,
                       c_shard))
    with mesh:
        lowered = jitted.lower(param_shapes, inputs["tokens"], cache_shapes,
                               inputs.get("embeds"))
    return lowered, _count(param_shapes), active_params(param_shapes, cfg)


def lower_decode(combo: shp.Combo, mesh):
    cfg = combo.arch
    dtype = jnp.bfloat16
    param_shapes, p_shard, inputs, in_shard = build_abstract(combo, mesh,
                                                             dtype)
    cache_shapes = shp.cache_specs(combo, dtype)
    c_shard = shard_cache(cache_shapes, mesh, combo.batch)
    step = make_decode_step(cfg)
    jitted = jax.jit(step,
                     in_shardings=(p_shard, in_shard["tokens"], c_shard),
                     out_shardings=(None, c_shard))
    with mesh:
        lowered = jitted.lower(param_shapes, inputs["tokens"], cache_shapes)
    return lowered, _count(param_shapes), active_params(param_shapes, cfg)


def _opt_flags(mesh, combo):
    """§Perf lever settings for --opt mode (see models/runtime_flags.py)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import data_axes
    daxes = data_axes(mesh)
    batch_ax = daxes if combo.batch % int(
        np.prod([mesh.shape[a] for a in daxes])) == 0 else None
    return dict(
        seq_parallel_spec=P(batch_ax, "model", None),
        attn_chunk=2048,
        moe_group=512,
        exp_in_spec=P("model", batch_ax, None, None),
        dispatch_spec=P(batch_ax, None, "model", None),
        decode_inplace=True,
        mesh=mesh,
    )


def run_combo(arch_id: str, shape_id: str, multi_pod: bool,
              compile_: bool = True, opt: bool = False) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    combo = shp.resolve(configs.get(arch_id), shape_id)
    lower_fn = {"train": lower_train, "prefill": lower_prefill,
                "decode": lower_decode}[combo.kind]
    if opt:
        from repro.models.runtime_flags import perf_flags
        with perf_flags(**_opt_flags(mesh, combo)):
            lowered, n_params, n_active = lower_fn(combo, mesh)
    else:
        lowered, n_params, n_active = lower_fn(combo, mesh)
    rec = {
        "arch": arch_id, "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": combo.kind, "windowed": combo.windowed, "opt": opt,
        "n_params": n_params, "n_active": n_active,
        "lower_s": round(time.time() - t0, 1),
    }
    if not compile_:
        return rec
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)
    rec["memory"] = memory_report(compiled)
    n_chips = int(mesh.devices.size)
    n_tokens = combo.batch * (combo.seq_len if combo.kind == "train"
                              else combo.seq_len if combo.kind == "prefill"
                              else 1)
    mflops = lm_model_flops(n_active, n_tokens,
                            "train" if combo.kind == "train" else "serve")
    hlo = compiled.as_text()
    terms = analyze(compiled, hlo, n_chips, model_flops=mflops)
    rec["roofline"] = terms.as_dict()
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", choices=shp.SHAPE_IDS)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="enable the §Perf levers (seq-parallel residual, "
                         "chunked attention, MoE constraints)")
    args = ap.parse_args()

    combos = []
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[
        args.mesh]
    if args.all:
        for a in configs.ARCH_IDS:
            for s in shp.SHAPE_IDS:
                for mp in meshes:
                    combos.append((a, s, mp))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape, mp) for mp in meshes]

    out_f = open(args.out, "a") if args.out else None
    failures = 0
    for a, s, mp in combos:
        tag = f"{a} x {s} x {'2x16x16' if mp else '16x16'}"
        try:
            rec = run_combo(a, s, mp, compile_=not args.no_compile,
                            opt=args.opt)
            r = rec.get("roofline", {})
            print(f"OK   {tag}: bottleneck={r.get('bottleneck')} "
                  f"compute={r.get('compute_s', 0):.3e}s "
                  f"memory={r.get('memory_s', 0):.3e}s "
                  f"coll={r.get('collective_s', 0):.3e}s "
                  f"(lower {rec['lower_s']}s compile "
                  f"{rec.get('compile_s')}s)", flush=True)
        except Exception as e:
            failures += 1
            rec = {"arch": a, "shape": s, "mesh": mp, "error": repr(e),
                   "traceback": traceback.format_exc()}
            print(f"FAIL {tag}: {e!r}", flush=True)
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
            out_f.flush()
        gc.collect()
    if out_f:
        out_f.close()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Generalized generative processes (paper §4.1–4.2).

The single update rule Eq. 12 covers the whole family:

  x_s = sqrt(a_s) * x0_hat(x_t)                         "predicted x0"
      + sqrt(1 - a_s - sigma_t^2) * eps_theta(x_t)      "direction to x_t"
      + sigma_t * eps                                   "random noise"

with sigma given by Eq. 16: eta=0 -> DDIM (deterministic, implicit model),
eta=1 -> DDPM, and the over-dispersed sigma-hat variant of Ho et al.'s
CIFAR10 runs. The trajectory runs over a sub-sequence tau (§4.2) so S << T
network evaluations produce a sample.

The full S-step loop is one ``jax.lax.scan`` — a single XLA program, the TPU
analogue of CUDA-graph capture (no host round-trips between steps).

Two scan-body implementations:

  * the pure-jnp ``StepImpl`` path (default) — the oracle. A drop-in fused
    kernel (kernels/ddim_step) can replace the update, but the state still
    enters/exits the kernel's padded tile layout every step.
  * the tile-resident path (``tile_resident=True``) — the production hot
    path. x_T is converted to the padded (R, C) tile layout ONCE, the whole
    scan carries that layout (kernels/sampler_step fuses x0-prediction,
    optional clipping, the Eq. 12 update, and in-kernel noise generation),
    and the natural shape is restored ONCE at the end. Per-step PRNG seeds
    are drawn before the scan, so the deterministic (eta=0) program
    contains no random ops inside the loop at all.

Besides the whole-trajectory scan there is a SINGLE-STEP API for the
continuous-batching scheduler (serving/scheduler): ``step_table`` lays a
request's trajectory out as host-side per-step rows, ``StepStates``
carries one (t, coefficients, seed) row PER SLOT, and ``sample_step`` /
``slot_tile_step`` advance a whole slot batch one step with every slot at
its own position in its own trajectory (kernels/sampler_step per-row
coefficient mode). eta=0 slot trajectories are bit-identical to the
tile-resident scan at the same S.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .diffusion import EpsFn, _bcast, predict_x0
from .schedules import NoiseSchedule, make_tau

# A fused update implementation: (x, eps, noise, c_x0, c_dir, c_noise,
# sqrt_a_t, sqrt_1m_a_t) -> x_prev. Injectable so the Pallas kernel
# (kernels/ddim_step) can replace the pure-jnp path without a circular import.
StepImpl = Callable[..., jnp.ndarray]


def _jnp_step(x, eps, noise, c_x0, c_dir, c_noise, sqrt_a_t, sqrt_1m_a_t):
    """Reference fused Eq.12 update (pure jnp).

    ``noise`` is None on the deterministic (eta=0, no sigma-hat) path —
    the noise term is skipped entirely rather than multiplied by zero.
    """
    x0 = (x - sqrt_1m_a_t * eps) / sqrt_a_t
    out = c_x0 * x0 + c_dir * eps
    if noise is not None:
        out = out + c_noise * noise
    return out


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """How to produce samples from a trained eps-model (paper §5 knobs)."""

    S: int = 50                       # dim(tau): number of sampler steps
    eta: float = 0.0                  # 0 = DDIM, 1 = DDPM (Eq. 16)
    tau_kind: str = "linear"          # 'linear' | 'quadratic' (App. D.2)
    sigma_hat: bool = False           # over-dispersed DDPM variant (App. D.3)
    clip_x0: Optional[float] = None   # clip predicted x0 (common practice)

    def __post_init__(self):
        if self.sigma_hat and self.eta != 1.0:
            raise ValueError("sigma_hat is a DDPM (eta=1) variant")


def trajectory_coefficients(schedule: NoiseSchedule, cfg: SamplerConfig):
    """Precompute per-step scalar coefficients for the Eq. 12 update.

    Returns dict of (S,) arrays: t (current step), and the five coefficients
    consumed by the fused step. Computed in float64-free numpy->jnp once, so
    the scan body is pure FMA work.
    """
    tau = make_tau(schedule.T, cfg.S, cfg.tau_kind)          # increasing, len S
    t_cur = jnp.asarray(tau, dtype=jnp.int32)
    t_prev = jnp.asarray(np.concatenate([[0], tau[:-1]]), dtype=jnp.int32)

    a_t = schedule.alpha_bar[t_cur]
    a_s = schedule.alpha_bar[t_prev]
    sigma = cfg.eta * jnp.sqrt((1.0 - a_s) / (1.0 - a_t)) * jnp.sqrt(
        1.0 - a_t / a_s)
    if cfg.sigma_hat:
        noise_scale = jnp.sqrt(1.0 - a_t / a_s)   # hat-sigma: bigger noise
    else:
        noise_scale = sigma
    # last step (t -> 0): the generative process draws x0 with std sigma_1
    # (Eq. 10 case t=1); the direction term vanishes since a_0 = 1.
    c_dir = jnp.sqrt(jnp.clip(1.0 - a_s - sigma ** 2, 0.0, None))
    return dict(
        t=t_cur,
        sqrt_a_t=jnp.sqrt(a_t),
        sqrt_1m_a_t=jnp.sqrt(1.0 - a_t),
        c_x0=jnp.sqrt(a_s),
        c_dir=c_dir,
        c_noise=noise_scale,
    )


class StepStates(NamedTuple):
    """Per-slot step state for one scheduler tick (all arrays length B).

    Slot b sits at its own position of its own trajectory: ``t[b]`` is the
    current timestep fed to the eps model and the five coefficient vectors
    are that position's Eq. 12 row (one row of ``step_table``). ``seed`` is
    the per-slot per-tick noise seed (stochastic engines only). A NamedTuple
    so it flows through jax.jit as a pytree — changing slot CONTENTS never
    changes the tick's trace.
    """

    t: jnp.ndarray
    c_x0: jnp.ndarray
    c_dir: jnp.ndarray
    c_noise: jnp.ndarray
    sqrt_a_t: jnp.ndarray
    sqrt_1m_a_t: jnp.ndarray
    seed: Optional[jnp.ndarray] = None

    def coef_matrix(self) -> jnp.ndarray:
        """(B, 5) float32 rows in the kernel's column order."""
        return jnp.stack([self.c_x0, self.c_dir, self.c_noise,
                          self.sqrt_a_t, self.sqrt_1m_a_t],
                         axis=1).astype(jnp.float32)


def step_table(schedule: NoiseSchedule, cfg: SamplerConfig):
    """Host-side per-request step table for the single-step scheduler path.

    ``trajectory_coefficients`` reversed into SAMPLING order and pulled to
    numpy: row k holds the (t, c_x0, c_dir, c_noise, sqrt_a_t, sqrt_1m_a_t)
    the k-th tick of a request consumes (k=0 is t=tau_S, k=S-1 ends at
    x_0). The scheduler gathers one row per resident slot per tick.
    """
    coefs = trajectory_coefficients(schedule, cfg)
    return {k: np.ascontiguousarray(np.asarray(v)[::-1])
            for k, v in coefs.items()}


def slot_tile_step(eps_fn, x2: jnp.ndarray, states: StepStates, shape, *,
                   clip_x0=None, stochastic: bool = False,
                   want_x0: bool = False, hw_prng: bool = False,
                   interpret: bool = True):
    """One scheduler tick over the slot-tile view — the jit-once tick body.

    ``x2`` is the (B * rows_per_slot, C) slot-tile layout owned by the
    engine (kernels/sampler_step/ops.to_slot_tile_layout); ``shape`` is the
    per-slot natural sample shape. eps models declaring
    ``slot_tile_aware = True`` receive (x2, t (B,)) directly; otherwise an
    adapter restores the natural (B, *shape) view around the eps call.
    Returns the advanced view (plus the x0-preview view when ``want_x0``).
    """
    from repro.kernels.sampler_step import ops as tile_ops

    B = states.t.shape[0]
    rps = x2.shape[0] // B
    if getattr(eps_fn, "slot_tile_aware", False):
        eps2 = eps_fn(x2, states.t)
    else:
        n = int(np.prod(shape))
        x_nat = tile_ops.from_slot_tile_layout(x2, n, (B,) + tuple(shape))
        eps2, _ = tile_ops.to_slot_tile_layout(eps_fn(x_nat, states.t))
    row_coefs = tile_ops.expand_slot_coefs(states.coef_matrix(), rps)
    row_seeds = (tile_ops.derive_row_seeds(states.seed, rps)
                 if stochastic else None)
    return tile_ops.sampler_step_rows(
        x2, eps2, row_coefs, row_seeds, clip=clip_x0, stochastic=stochastic,
        want_x0=want_x0, hw_prng=hw_prng, interpret=interpret)


def sample_step(schedule: NoiseSchedule, eps_fn, x: jnp.ndarray,
                states: StepStates, *, clip_x0=None,
                stochastic: bool = False, want_x0: bool = False,
                interpret: Optional[bool] = None):
    """Advance a slot batch ONE step, each row at its own trajectory position.

    The natural-shape convenience wrapper around ``slot_tile_step`` (one
    layout conversion in, one out per call). The engine itself keeps the
    state tile-resident across a slot's whole lifetime and only converts at
    admission/retirement; use this entry for standalone/step-debug use.
    ``schedule`` is unused (coefficients arrive pre-gathered in ``states``)
    but kept for signature symmetry with ``sample``.
    """
    del schedule
    from repro.kernels.sampler_step import ops as tile_ops

    if interpret is None:
        interpret = tile_ops.default_interpret()
    x2, n = tile_ops.to_slot_tile_layout(x)
    out = slot_tile_step(eps_fn, x2, states, x.shape[1:], clip_x0=clip_x0,
                         stochastic=stochastic, want_x0=want_x0,
                         hw_prng=tile_ops.default_hw_prng(interpret),
                         interpret=interpret)
    if want_x0:
        return tuple(tile_ops.from_slot_tile_layout(o, n, x.shape)
                     for o in out)
    return tile_ops.from_slot_tile_layout(out, n, x.shape)


def _tile_resident_sample(schedule, eps_fn, x_T, cfg, rng,
                          return_trajectory, interpret):
    """S-step scan carried entirely in the kernel's padded (R, C) layout.

    One layout conversion on entry, one on exit (the layout contract —
    kernels/sampler_step/ops.py). The fused kernel does x0-prediction,
    optional clipping + eps re-derivation, the Eq. 12 update and (for
    stochastic processes) in-kernel noise generation, so the scan body
    touches HBM once per input and once for the output.
    """
    from repro.kernels.sampler_step import ops as tile_ops

    if interpret is None:  # interpreter everywhere except a real TPU
        interpret = tile_ops.default_interpret()
    stochastic = cfg.eta > 0.0 or cfg.sigma_hat
    coefs = trajectory_coefficients(schedule, cfg)
    rev = jax.tree.map(lambda a: a[::-1], coefs)
    batch, shape = x_T.shape[0], x_T.shape
    hw_prng = tile_ops.default_hw_prng(interpret)
    # all randomness outside the scan: per-step int32 seeds, one per tile
    # family; the deterministic program never touches the PRNG at all
    seeds = (jax.random.randint(rng, (cfg.S,), 0, np.iinfo(np.int32).max,
                                dtype=jnp.int32)
             if stochastic else None)
    tile_aware = getattr(eps_fn, "tile_aware", False)

    x2, n = tile_ops.to_tile_layout(x_T)             # conversion #1 (entry)

    def body(x2, per_step):
        c, seed = per_step
        cvec = jnp.stack([c["c_x0"], c["c_dir"], c["c_noise"],
                          c["sqrt_a_t"], c["sqrt_1m_a_t"]])
        if tile_aware:
            eps2 = eps_fn(x2, c["t"])                # native (R, C) model
        else:
            x_view = tile_ops.from_tile_layout(x2, n, shape)
            t = jnp.full((batch,), c["t"], dtype=jnp.int32)
            eps2, _ = tile_ops.to_tile_layout(eps_fn(x_view, t))
        x2_prev = tile_ops.sampler_step_tiles(
            x2, eps2, cvec, seed, clip=cfg.clip_x0, stochastic=stochastic,
            hw_prng=hw_prng, interpret=interpret)
        return x2_prev, (x2_prev if return_trajectory else None)

    x2_0, traj2 = jax.lax.scan(body, x2, (rev, seeds))
    x0 = tile_ops.from_tile_layout(x2_0, n, shape)   # conversion #2 (exit)
    if return_trajectory:
        traj = jax.vmap(lambda a: tile_ops.from_tile_layout(a, n, shape))(
            traj2)
        return x0, jnp.concatenate([x_T[None], traj], axis=0)
    return x0


def sample(schedule: NoiseSchedule, eps_fn: EpsFn, x_T: jnp.ndarray,
           cfg: SamplerConfig, rng: Optional[jax.Array] = None,
           step_impl: StepImpl = _jnp_step,
           return_trajectory: bool = False,
           tile_resident: bool = False,
           interpret: Optional[bool] = None) -> jnp.ndarray:
    """Run the generalized generative process from x_T to x_0.

    Args:
      schedule: noise schedule the model was trained with (T steps).
      eps_fn: eps_theta(x_t, t) with t an int32 (batch,) array. On the
        tile-resident path a model may declare ``eps_fn.tile_aware = True``
        to receive the (R, C) tile view and a scalar t directly (elementwise
        models); otherwise a view-restoring adapter shows it the natural
        shape.
      x_T: initial latent, N(0, I) for generation or an encoding (ode.encode).
      cfg: sampler configuration (S, eta, tau spacing, ...).
      rng: PRNG key; required iff the process is stochastic (eta>0/sigma_hat).
      step_impl: fused update implementation (default pure-jnp; the Pallas
        kernel from repro.kernels.ddim_step is a drop-in). Ignored when
        tile_resident.
      return_trajectory: also return the (S+1, ...) stack of iterates.
      tile_resident: run the scan in the Pallas tile layout end-to-end
        (kernels/sampler_step) — the production hot path.
      interpret: Pallas interpret mode; None (default) resolves to
        "everywhere except a real TPU". Only used when tile_resident.
    """
    stochastic = cfg.eta > 0.0 or cfg.sigma_hat
    if stochastic and rng is None:
        raise ValueError("stochastic sampler (eta>0 or sigma_hat) needs rng")
    if rng is None:
        rng = jax.random.PRNGKey(0)  # unused: deterministic path draws none
    if tile_resident:
        return _tile_resident_sample(schedule, eps_fn, x_T, cfg, rng,
                                     return_trajectory, interpret)
    coefs = trajectory_coefficients(schedule, cfg)
    batch = x_T.shape[0]

    def body(x, per_step):
        c, key = per_step
        t = jnp.full((batch,), c["t"], dtype=jnp.int32)
        eps = eps_fn(x, t)
        if cfg.clip_x0 is not None:
            # clipping predicted x0 re-derives an equivalent eps
            x0 = predict_x0(schedule, x, t, eps, clip=cfg.clip_x0)
            eps = (x - jnp.sqrt(schedule.alpha_bar[c["t"]]) * x0) / jnp.sqrt(
                1.0 - schedule.alpha_bar[c["t"]])
        noise = (jax.random.normal(key, x.shape, dtype=x.dtype)
                 if stochastic else None)
        x_prev = step_impl(
            x, eps, noise,
            c["c_x0"].astype(x.dtype), c["c_dir"].astype(x.dtype),
            c["c_noise"].astype(x.dtype), c["sqrt_a_t"].astype(x.dtype),
            c["sqrt_1m_a_t"].astype(x.dtype))
        return x_prev, (x_prev if return_trajectory else None)

    # iterate from the largest timestep down: reverse the coefficient arrays
    rev = jax.tree.map(lambda a: a[::-1], coefs)
    keys = jax.random.split(rng, cfg.S) if stochastic else None
    x0, traj = jax.lax.scan(body, x_T, (rev, keys))
    if return_trajectory:
        return x0, jnp.concatenate([x_T[None], traj], axis=0)
    return x0


def ddim_sample(schedule: NoiseSchedule, eps_fn: EpsFn, x_T: jnp.ndarray,
                S: int = 50, tau_kind: str = "linear",
                **kw) -> jnp.ndarray:
    """Deterministic DDIM (eta = 0) — the paper's headline sampler."""
    return sample(schedule, eps_fn, x_T,
                  SamplerConfig(S=S, eta=0.0, tau_kind=tau_kind), **kw)


def ddpm_sample(schedule: NoiseSchedule, eps_fn: EpsFn, x_T: jnp.ndarray,
                rng: jax.Array, S: Optional[int] = None,
                tau_kind: str = "linear", sigma_hat: bool = False,
                **kw) -> jnp.ndarray:
    """DDPM baseline (eta = 1), optionally the sigma-hat variant."""
    S = S if S is not None else schedule.T
    return sample(schedule, eps_fn, x_T,
                  SamplerConfig(S=S, eta=1.0, tau_kind=tau_kind,
                                sigma_hat=sigma_hat), rng=rng, **kw)

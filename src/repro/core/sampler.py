"""Generalized generative processes (paper §4.1–4.2).

The single update rule Eq. 12 covers the whole family:

  x_s = sqrt(a_s) * x0_hat(x_t)                         "predicted x0"
      + sqrt(1 - a_s - sigma_t^2) * eps_theta(x_t)      "direction to x_t"
      + sigma_t * eps                                   "random noise"

with sigma given by Eq. 16: eta=0 -> DDIM (deterministic, implicit model),
eta=1 -> DDPM, and the over-dispersed sigma-hat variant of Ho et al.'s
CIFAR10 runs. The trajectory runs over a sub-sequence tau (§4.2) so S << T
network evaluations produce a sample.

THE FRONT DOOR for all of this is now ``repro.sampling.SamplerPlan``: a
declarative (TauSpec, SigmaSpec, X0Policy, solver order) bundle compiled
once into the canonical per-step coefficient table and executed on any
backend (``plan.run(..., backend='jnp'|'tile_resident'|'rows')``, plus
``plan.encode`` for the ODE inversion).  This module keeps:

  * ``SamplerConfig`` + ``sample()`` — the stable convenience entry,
    now a thin adapter that builds a plan and dispatches a backend
    ('tile_resident' flag -> the Pallas tile-resident scan);
  * ``trajectory_coefficients`` / ``step_table`` — coefficient views read
    from the SAME compiled plan (one coefficient program repo-wide);
  * the SINGLE-STEP API for the continuous-batching scheduler
    (``StepStates`` / ``sample_step`` / ``slot_tile_step``), extended with
    optional per-slot Adams–Bashforth solver state so the scheduler can
    mix solver orders across resident slots;
  * DEPRECATED wrappers ``ddim_sample`` / ``ddpm_sample`` (and the
    injectable ``step_impl`` scan) — thin shims over plans that emit
    DeprecationWarning; no non-test call site uses them anymore.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import solver
from .diffusion import EpsFn, predict_x0
from .schedules import NoiseSchedule

# A fused update implementation: (x, eps, noise, c_x0, c_dir, c_noise,
# sqrt_a_t, sqrt_1m_a_t) -> x_prev. Injectable so the legacy Pallas kernel
# (kernels/ddim_step) can replace the pure-jnp path without a circular
# import. DEPRECATED: build a SamplerPlan and pick a backend instead.
StepImpl = Callable[..., jnp.ndarray]


def _jnp_step(x, eps, noise, c_x0, c_dir, c_noise, sqrt_a_t, sqrt_1m_a_t):
    """Reference fused Eq.12 update (pure jnp) for the legacy StepImpl path.

    ``noise`` is None on the deterministic (eta=0, no sigma-hat) path —
    the noise term is skipped entirely rather than multiplied by zero.
    """
    x0 = (x - sqrt_1m_a_t * eps) / sqrt_a_t
    out = c_x0 * x0 + c_dir * eps
    if noise is not None:
        out = out + c_noise * noise
    return out


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """How to produce samples from a trained eps-model (paper §5 knobs).

    The scalar-knob subset of the full plan surface; ``to_plan`` lifts it.
    """

    S: int = 50                       # dim(tau): number of sampler steps
    eta: float = 0.0                  # 0 = DDIM, 1 = DDPM (Eq. 16)
    tau_kind: str = "linear"          # 'linear' | 'quadratic' (App. D.2)
    sigma_hat: bool = False           # over-dispersed DDPM variant (App. D.3)
    clip_x0: Optional[float] = None   # clip predicted x0 (common practice)

    def __post_init__(self):
        if self.sigma_hat and self.eta != 1.0:
            raise ValueError("sigma_hat is a DDPM (eta=1) variant")

    def to_plan(self, schedule: NoiseSchedule, order: int = 1):
        """The equivalent compiled SamplerPlan."""
        from repro.sampling import SamplerPlan
        return SamplerPlan.from_config(schedule, self, order=order)


def trajectory_coefficients(schedule: NoiseSchedule, cfg: SamplerConfig):
    """Per-step scalar coefficients for the Eq. 12 update (legacy view).

    Returns dict of (S,) arrays in TRAJECTORY order (increasing t): t and
    the five coefficients consumed by the fused step. Read from the
    compiled SamplerPlan so the whole repo shares one coefficient program.
    """
    return cfg.to_plan(schedule).coefficients()


class StepStates(NamedTuple):
    """Per-slot step state for one scheduler tick (all arrays length B).

    Slot b sits at its own position of its own trajectory: ``t[b]`` is the
    current timestep fed to the eps model and the five coefficient vectors
    are that position's Eq. 12 row (one row of the slot plan's table).
    ``seed`` is the per-slot per-tick noise seed (stochastic engines only);
    ``solver_w`` is the per-slot (B, max_order) Adams–Bashforth weight row
    (multistep-capable engines only — None keeps the order-1 tick's pytree
    unchanged). A NamedTuple so it flows through jax.jit as a pytree —
    changing slot CONTENTS never changes the tick's trace.
    """

    t: jnp.ndarray
    c_x0: jnp.ndarray
    c_dir: jnp.ndarray
    c_noise: jnp.ndarray
    sqrt_a_t: jnp.ndarray
    sqrt_1m_a_t: jnp.ndarray
    seed: Optional[jnp.ndarray] = None
    solver_w: Optional[jnp.ndarray] = None

    def coef_matrix(self) -> jnp.ndarray:
        """(B, 5) float32 rows in the kernel's column order."""
        return jnp.stack([self.c_x0, self.c_dir, self.c_noise,
                          self.sqrt_a_t, self.sqrt_1m_a_t],
                         axis=1).astype(jnp.float32)


def step_table(schedule: NoiseSchedule, cfg: SamplerConfig):
    """Host-side per-request step table for the single-step scheduler path.

    The compiled plan's table in SAMPLING order: row k holds the
    (t, c_x0, c_dir, c_noise, sqrt_a_t, sqrt_1m_a_t) the k-th tick of a
    request consumes (k=0 is t=tau_S, k=S-1 ends at x_0), plus the
    (S, order) ``solver_w`` Adams–Bashforth weights. The scheduler gathers
    one row per resident slot per tick.
    """
    return cfg.to_plan(schedule).steps()


def slot_tile_step(eps_fn, x2: jnp.ndarray, states: StepStates, shape, *,
                   hist2: Optional[jnp.ndarray] = None, clip_x0=None,
                   stochastic: bool = False, want_x0: bool = False,
                   want_eps: bool = False,
                   hw_prng: bool = False, interpret: bool = True):
    """One scheduler tick over the slot-tile view — the jit-once tick body.

    ``x2`` is the (B * rows_per_slot, C) slot-tile layout owned by the
    engine (kernels/sampler_step/ops.to_slot_tile_layout); ``shape`` is the
    per-slot natural sample shape. eps models declaring
    ``slot_tile_aware = True`` receive (x2, t (B,)) directly; otherwise an
    adapter restores the natural (B, *shape) view around the eps call.

    Multistep engines pass ``hist2`` — the (max_order-1, R, C) float32
    stack of previous eps evaluations, newest first — and per-slot
    ``states.solver_w`` weights; each slot's effective eps becomes its own
    Adams–Bashforth combination (order-1 slots carry weight rows [1, 0...]
    and ride along unchanged). Returns the advanced view (plus the
    x0-preview view when ``want_x0``); with ``hist2`` the return is
    ``(step_out, new_hist2)``. ``want_eps`` additionally appends the RAW
    (pre-solver-mix) eps evaluation in tile layout — the engine's probed
    tick reduces it on-device (obs/probes.py) without a second eval.
    """
    from repro.kernels.sampler_step import ops as tile_ops

    B = states.t.shape[0]
    rps = x2.shape[0] // B
    if getattr(eps_fn, "slot_tile_aware", False):
        eps2 = eps_fn(x2, states.t)
    else:
        n = int(np.prod(shape))
        x_nat = tile_ops.from_slot_tile_layout(x2, n, (B,) + tuple(shape))
        eps2, _ = tile_ops.to_slot_tile_layout(eps_fn(x_nat, states.t))
    eps_raw2 = eps2 if want_eps else None
    new_hist2 = None
    if hist2 is not None:
        # per-slot Adams–Bashforth combine: each row's effective eps is a
        # weighted sum of the current eval and the slot's history (pure
        # FMA work — slot mixes change VALUES only, never the trace);
        # the weight stack is (order, rows, 1) so every slot applies its
        # own row through the one shared combine implementation
        order = states.solver_w.shape[1]
        w_stack = jnp.repeat(states.solver_w.astype(jnp.float32), rps,
                             axis=0).T[:, :, None]
        eps2, new_hist2 = solver.mix_history(eps2.astype(jnp.float32),
                                             hist2, w_stack, order)
    row_coefs = tile_ops.expand_slot_coefs(states.coef_matrix(), rps)
    row_seeds = (tile_ops.derive_row_seeds(states.seed, rps)
                 if stochastic else None)
    out = tile_ops.sampler_step_rows(
        x2, eps2, row_coefs, row_seeds, clip=clip_x0, stochastic=stochastic,
        want_x0=want_x0, hw_prng=hw_prng, interpret=interpret)
    if hist2 is not None:
        if want_eps:
            return out, new_hist2, eps_raw2
        return out, new_hist2
    if want_eps:
        return out, eps_raw2
    return out


def sample_step(schedule: NoiseSchedule, eps_fn, x: jnp.ndarray,
                states: StepStates, *, clip_x0=None,
                stochastic: bool = False, want_x0: bool = False,
                interpret: Optional[bool] = None):
    """Advance a slot batch ONE step, each row at its own trajectory position.

    The natural-shape convenience wrapper around ``slot_tile_step`` (one
    layout conversion in, one out per call; order-1 steps only — the
    engine owns solver history). The engine itself keeps the state
    tile-resident across a slot's whole lifetime and only converts at
    admission/retirement; use this entry for standalone/step-debug use.
    ``schedule`` is unused (coefficients arrive pre-gathered in ``states``)
    but kept for signature symmetry with ``sample``.
    """
    del schedule
    from repro.kernels.sampler_step import ops as tile_ops

    if interpret is None:
        interpret = tile_ops.default_interpret()
    x2, n = tile_ops.to_slot_tile_layout(x)
    out = slot_tile_step(eps_fn, x2, states, x.shape[1:], clip_x0=clip_x0,
                         stochastic=stochastic, want_x0=want_x0,
                         hw_prng=tile_ops.default_hw_prng(interpret),
                         interpret=interpret)
    if want_x0:
        return tuple(tile_ops.from_slot_tile_layout(o, n, x.shape)
                     for o in out)
    return tile_ops.from_slot_tile_layout(out, n, x.shape)


def _legacy_step_impl_sample(schedule, eps_fn, x_T, cfg, rng, step_impl,
                             return_trajectory):
    """The injectable-StepImpl scan (deprecated migration baseline).

    Pays a per-step layout conversion when the StepImpl is a Pallas
    kernel wrapper — exactly the traffic the tile-resident backend
    removes; kept so the regression contrast stays testable.
    """
    stochastic = cfg.eta > 0.0 or cfg.sigma_hat
    coefs = trajectory_coefficients(schedule, cfg)
    batch = x_T.shape[0]

    def body(x, per_step):
        c, key = per_step
        t = jnp.full((batch,), c["t"], dtype=jnp.int32)
        eps = eps_fn(x, t)
        if cfg.clip_x0 is not None:
            # clipping predicted x0 re-derives an equivalent eps
            x0 = predict_x0(schedule, x, t, eps, clip=cfg.clip_x0)
            eps = (x - jnp.sqrt(schedule.alpha_bar[c["t"]]) * x0) / jnp.sqrt(
                1.0 - schedule.alpha_bar[c["t"]])
        noise = (jax.random.normal(key, x.shape, dtype=x.dtype)
                 if stochastic else None)
        x_prev = step_impl(
            x, eps, noise,
            c["c_x0"].astype(x.dtype), c["c_dir"].astype(x.dtype),
            c["c_noise"].astype(x.dtype), c["sqrt_a_t"].astype(x.dtype),
            c["sqrt_1m_a_t"].astype(x.dtype))
        return x_prev, (x_prev if return_trajectory else None)

    # iterate from the largest timestep down: reverse the coefficient arrays
    rev = jax.tree.map(lambda a: a[::-1], coefs)
    keys = jax.random.split(rng, cfg.S) if stochastic else None
    x0, traj = jax.lax.scan(body, x_T, (rev, keys))
    if return_trajectory:
        return x0, jnp.concatenate([x_T[None], traj], axis=0)
    return x0


def sample(schedule: NoiseSchedule, eps_fn: EpsFn, x_T: jnp.ndarray,
           cfg: SamplerConfig, rng: Optional[jax.Array] = None,
           step_impl: StepImpl = _jnp_step,
           return_trajectory: bool = False,
           tile_resident: bool = False,
           interpret: Optional[bool] = None,
           backend: Optional[str] = None) -> jnp.ndarray:
    """Run the generalized generative process from x_T to x_0.

    A thin adapter over ``repro.sampling.SamplerPlan``: builds the plan for
    ``cfg`` and runs the 'jnp' backend (or 'tile_resident' when asked).
    For trajectories the scalar knobs cannot express — learned tau,
    per-step eta schedules, explicit sigmas, multistep solver orders —
    build the plan directly.

    Args:
      schedule: noise schedule the model was trained with (T steps).
      eps_fn: eps_theta(x_t, t) with t an int32 (batch,) array. On the
        tile-resident path a model may declare ``eps_fn.tile_aware = True``
        to receive the (R, C) tile view and a scalar t directly (elementwise
        models); otherwise a view-restoring adapter shows it the natural
        shape.
      x_T: initial latent, N(0, I) for generation or an encoding
        (SamplerPlan.encode / ode.encode).
      cfg: sampler configuration (S, eta, tau spacing, ...).
      rng: PRNG key; required iff the process is stochastic (eta>0/sigma_hat).
      step_impl: DEPRECATED injectable fused-update implementation; passing
        anything but the default runs the legacy per-step scan and warns.
        Ignored when tile_resident.
      return_trajectory: also return the (S+1, ...) stack of iterates.
      tile_resident: run the scan in the Pallas tile layout end-to-end
        (kernels/sampler_step) — the production hot path.
      interpret: Pallas interpret mode; None (default) resolves to
        "everywhere except a real TPU". Only used on kernel backends.
      backend: explicit SamplerPlan backend name
        ('jnp' | 'tile_resident' | 'rows' | 'mega'); overrides the
        ``tile_resident`` flag when given. 'mega' fuses the eps trunk into
        the step kernel for mega-eligible models and falls back to
        'tile_resident' otherwise.
    """
    stochastic = cfg.eta > 0.0 or cfg.sigma_hat
    if stochastic and rng is None:
        raise ValueError("stochastic sampler (eta>0 or sigma_hat) needs rng")
    if step_impl is not _jnp_step and not tile_resident:
        warnings.warn(
            "sample(step_impl=...) is deprecated: build a "
            "repro.sampling.SamplerPlan and pick a backend "
            "(run(..., backend='tile_resident') is the fused hot path)",
            DeprecationWarning, stacklevel=2)
        return _legacy_step_impl_sample(schedule, eps_fn, x_T, cfg, rng,
                                        step_impl, return_trajectory)
    plan = cfg.to_plan(schedule)
    if backend is None:
        backend = "tile_resident" if tile_resident else "jnp"
    return plan.run(eps_fn, x_T, rng, backend=backend,
                    return_trajectory=return_trajectory,
                    interpret=interpret)


def ddim_sample(schedule: NoiseSchedule, eps_fn: EpsFn, x_T: jnp.ndarray,
                S: int = 50, tau_kind: str = "linear",
                **kw) -> jnp.ndarray:
    """DEPRECATED: use ``SamplerPlan.build(schedule, tau=S).run(...)``.

    Deterministic DDIM (eta = 0) — the paper's headline sampler. Kept as a
    thin shim over the plan API for old call sites and regression tests.
    """
    warnings.warn("ddim_sample is deprecated: use repro.sampling."
                  "SamplerPlan.build(schedule, tau=S).run(eps_fn, x_T)",
                  DeprecationWarning, stacklevel=2)
    return sample(schedule, eps_fn, x_T,
                  SamplerConfig(S=S, eta=0.0, tau_kind=tau_kind), **kw)


def ddpm_sample(schedule: NoiseSchedule, eps_fn: EpsFn, x_T: jnp.ndarray,
                rng: jax.Array, S: Optional[int] = None,
                tau_kind: str = "linear", sigma_hat: bool = False,
                **kw) -> jnp.ndarray:
    """DEPRECATED: use ``SamplerPlan.build(schedule, tau=S, sigma=1.0)``.

    DDPM baseline (eta = 1), optionally the sigma-hat variant. Kept as a
    thin shim over the plan API for old call sites and regression tests.
    """
    warnings.warn(
        "ddpm_sample is deprecated: use repro.sampling.SamplerPlan.build("
        "schedule, tau=S, sigma=SigmaSpec.ddpm(...)).run(eps_fn, x_T, rng)",
        DeprecationWarning, stacklevel=2)
    S = S if S is not None else schedule.T
    return sample(schedule, eps_fn, x_T,
                  SamplerConfig(S=S, eta=1.0, tau_kind=tau_kind,
                                sigma_hat=sigma_hat), rng=rng, **kw)

"""Forward process, x0-prediction and training losses (paper §2–§3).

Everything here is a pure function of (schedule, arrays); the ε-network is
passed in as ``eps_fn(x_t, t) -> eps`` where ``t`` is an int32 array of
timesteps (one per batch element, values in [1, T]).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .schedules import NoiseSchedule

EpsFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _bcast(coef: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Broadcast per-batch scalar coefficients over trailing dims of x."""
    return coef.reshape(coef.shape + (1,) * (x.ndim - coef.ndim))


def q_sample(schedule: NoiseSchedule, x0: jnp.ndarray, t: jnp.ndarray,
             noise: jnp.ndarray) -> jnp.ndarray:
    """Sample x_t ~ q(x_t | x0) = N(sqrt(a_t) x0, (1-a_t) I)  (paper Eq. 4)."""
    a = schedule.alpha_bar[t]
    return _bcast(jnp.sqrt(a), x0) * x0 + _bcast(jnp.sqrt(1.0 - a), x0) * noise


def predict_x0(schedule: NoiseSchedule, x_t: jnp.ndarray, t: jnp.ndarray,
               eps: jnp.ndarray, clip: Optional[float] = None) -> jnp.ndarray:
    """Denoised observation f_theta (paper Eq. 9)."""
    a = schedule.alpha_bar[t]
    x0 = (x_t - _bcast(jnp.sqrt(1.0 - a), x_t) * eps) / _bcast(jnp.sqrt(a), x_t)
    if clip is not None:
        x0 = jnp.clip(x0, -clip, clip)
    return x0


def eps_from_x0(schedule: NoiseSchedule, x_t: jnp.ndarray, t: jnp.ndarray,
                x0: jnp.ndarray) -> jnp.ndarray:
    """Invert Eq. 9: the ε consistent with (x_t, x0)."""
    a = schedule.alpha_bar[t]
    return (x_t - _bcast(jnp.sqrt(a), x_t) * x0) / _bcast(
        jnp.sqrt(1.0 - a), x_t)


def posterior_sigma(schedule: NoiseSchedule, t: jnp.ndarray, s: jnp.ndarray,
                    eta: float | jnp.ndarray = 0.0) -> jnp.ndarray:
    """sigma_t(eta) of paper Eq. 16, generalized to a (t -> s) jump.

    eta=1 recovers the DDPM posterior std; eta=0 is DDIM (deterministic).
    """
    a_t = schedule.alpha_bar[t]
    a_s = schedule.alpha_bar[s]
    return eta * jnp.sqrt((1.0 - a_s) / (1.0 - a_t)) * jnp.sqrt(
        1.0 - a_t / a_s)


def sigma_hat(schedule: NoiseSchedule, t: jnp.ndarray,
              s: jnp.ndarray) -> jnp.ndarray:
    """The over-dispersed DDPM variance sqrt(1 - a_t/a_s) (paper §5, App D.3)."""
    return jnp.sqrt(1.0 - schedule.alpha_bar[t] / schedule.alpha_bar[s])


def gamma_weights(schedule: NoiseSchedule, sigma: jnp.ndarray,
                  d: int) -> jnp.ndarray:
    """Theorem-1 weights gamma_t = 1 / (2 d sigma_t^2 alpha_t), shape (T,).

    These make J_sigma == L_gamma + C; with parameter sharing across t the
    optimum coincides with L_1, which is why the paper trains only L_1.
    ``sigma`` must be positive (Theorem 1 requires sigma > 0).
    """
    a = schedule.alpha_bar[1:]
    return 1.0 / (2.0 * d * (sigma ** 2) * a)


def simple_loss(schedule: NoiseSchedule, eps_fn: EpsFn, x0: jnp.ndarray,
                t: jnp.ndarray, noise: jnp.ndarray,
                weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """L_gamma (paper Eq. 5). weights=None gives gamma=1, i.e. L_simple/L_1."""
    x_t = q_sample(schedule, x0, t, noise)
    eps_hat = eps_fn(x_t, t)
    per_ex = jnp.mean(jnp.square(eps_hat - noise),
                      axis=tuple(range(1, x0.ndim)))
    if weights is not None:
        per_ex = per_ex * weights[t - 1]
    return jnp.mean(per_ex)


def training_loss(schedule: NoiseSchedule, eps_fn: EpsFn, x0: jnp.ndarray,
                  rng: jax.Array,
                  weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Draw (t, ε) and evaluate the denoising loss — one training step's loss."""
    k_t, k_e = jax.random.split(rng)
    t = jax.random.randint(k_t, (x0.shape[0],), 1, schedule.T + 1)
    noise = jax.random.normal(k_e, x0.shape, dtype=x0.dtype)
    return simple_loss(schedule, eps_fn, x0, t, noise, weights)

"""Core DDIM library — the paper's contribution as composable JAX modules.

Sampling front door: ``repro.sampling.SamplerPlan`` (declarative tau /
sigma / x0 / solver-order specs compiled once and run on any backend).
The entries here are the stable functional surface over it; ddim_sample /
ddpm_sample / multistep_sample are deprecated shims.
"""
from .schedules import NoiseSchedule, make_schedule, make_tau
from .diffusion import (q_sample, predict_x0, eps_from_x0, posterior_sigma,
                        sigma_hat, gamma_weights, simple_loss, training_loss)
from .sampler import (SamplerConfig, StepStates, trajectory_coefficients,
                      sample, sample_step, slot_tile_step, step_table,
                      ddim_sample, ddpm_sample)
from .ode import encode, decode, probability_flow_sample, multistep_sample
from .interpolate import slerp, slerp_grid
from .extensions import (v_from_eps_x0, eps_from_v, x0_from_v,
                         eps_fn_from_v_fn, v_training_target, cfg_eps_fn)
from . import discrete

__all__ = [
    "NoiseSchedule", "make_schedule", "make_tau",
    "q_sample", "predict_x0", "eps_from_x0", "posterior_sigma", "sigma_hat",
    "gamma_weights", "simple_loss", "training_loss",
    "SamplerConfig", "StepStates", "trajectory_coefficients", "sample",
    "sample_step", "slot_tile_step", "step_table", "ddim_sample",
    "ddpm_sample",
    "encode", "decode", "probability_flow_sample", "multistep_sample",
    "slerp", "slerp_grid", "discrete",
    "v_from_eps_x0", "eps_from_v", "x0_from_v", "eps_fn_from_v_fn",
    "v_training_target", "cfg_eps_fn",
]

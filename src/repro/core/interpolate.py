"""Latent-space interpolation (paper §5.3, Appendix D.5)."""
from __future__ import annotations

import jax.numpy as jnp


def slerp(x0: jnp.ndarray, x1: jnp.ndarray, alpha: jnp.ndarray,
          eps: float = 1e-7) -> jnp.ndarray:
    """Spherical linear interpolation (Shoemake 1985; paper Eq. 67).

    x0, x1: latents of identical shape. alpha: scalar or (K,) coefficients.
    Returns (K, *x.shape) (or x.shape for scalar alpha).
    """
    flat0 = x0.reshape(-1)
    flat1 = x1.reshape(-1)
    cos = jnp.clip(jnp.dot(flat0, flat1) /
                   (jnp.linalg.norm(flat0) * jnp.linalg.norm(flat1) + eps),
                   -1.0 + eps, 1.0 - eps)
    theta = jnp.arccos(cos)
    alpha = jnp.asarray(alpha)
    scalar = alpha.ndim == 0
    a = alpha.reshape(-1, *([1] * x0.ndim))
    out = (jnp.sin((1.0 - a) * theta) * x0[None] +
           jnp.sin(a * theta) * x1[None]) / jnp.sin(theta)
    return out[0] if scalar else out


def slerp_grid(corners: jnp.ndarray, n: int) -> jnp.ndarray:
    """Grid interpolation from four corner latents (paper App. D.5).

    corners: (4, *shape) -> returns (n, n, *shape); rows interpolate the two
    corner pairs, columns interpolate across the interpolated rows.
    """
    alphas = jnp.linspace(0.0, 1.0, n)
    top = slerp(corners[0], corners[1], alphas)       # (n, ...)
    bot = slerp(corners[2], corners[3], alphas)       # (n, ...)
    rows = [slerp(top[i], bot[i], alphas) for i in range(n)]
    return jnp.stack(rows, axis=1)                    # (n_col, n_row, ...)

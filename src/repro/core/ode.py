"""ODE view of DDIM (paper §4.3) — encoding, probability-flow Euler, and
multistep solvers.

With x_bar = x/sqrt(a) and sigma = sqrt((1-a)/a), DDIM is Euler on
``d x_bar = eps_theta(x) d sigma`` (Eq. 14). Integrating forward in t encodes
x0 -> x_T (a latent the deterministic sampler reconstructs from — Table 2).

The implementations live in ``repro.sampling``: ``SamplerPlan.encode`` is
the forward direction on ANY plan trajectory (uniform/quadratic/learned
tau, Euler or Adams–Bashforth order), and a ``SamplerPlan(order=k)`` run is
the multistep sampler — the AB weights are baked into the plan's per-step
coefficient table, so the same program serves every backend and the
continuous-batching scheduler can mix solver orders across slots. This
module keeps the stable functional entries (``encode``/``decode``), the
probability-flow Euler discretization (a genuinely different scheme,
paper Eq. 15), and the DEPRECATED ``multistep_sample`` wrapper.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from .diffusion import EpsFn
from .schedules import NoiseSchedule, make_tau


def _sig(schedule: NoiseSchedule, t: jnp.ndarray) -> jnp.ndarray:
    a = schedule.alpha_bar[t]
    return jnp.sqrt((1.0 - a) / a)


def _plan(schedule: NoiseSchedule, S: int, tau_kind: str, order: int = 1):
    from repro.sampling import SamplerPlan, TauSpec
    kind = "uniform" if tau_kind == "linear" else tau_kind
    return SamplerPlan.build(schedule, tau=TauSpec(kind=kind, S=S),
                             order=order)


def encode(schedule: NoiseSchedule, eps_fn: EpsFn, x0: jnp.ndarray,
           S: int = 100, tau_kind: str = "linear") -> jnp.ndarray:
    """Run Eq. 13 forward in t: x0 -> x_T (deterministic latent).

    The reverse of DDIM sampling with the same trajectory tau; Euler steps
    in sigma with eps evaluated at the left (lower-noise) endpoint.
    Functional entry over ``SamplerPlan.encode`` — build a plan directly
    for quadratic/learned tau or multistep encoding.
    """
    return _plan(schedule, S, tau_kind).encode(eps_fn, x0)


def decode(schedule: NoiseSchedule, eps_fn: EpsFn, x_T: jnp.ndarray,
           S: int = 100, tau_kind: str = "linear") -> jnp.ndarray:
    """Deterministic reconstruction — the eta=0 plan run (kept here for
    symmetry with :func:`encode`)."""
    return _plan(schedule, S, tau_kind).run(eps_fn, x_T)


def probability_flow_sample(schedule: NoiseSchedule, eps_fn: EpsFn,
                            x_T: jnp.ndarray, S: int = 50,
                            tau_kind: str = "linear") -> jnp.ndarray:
    """Euler discretization of the probability-flow ODE (paper Eq. 15).

    Equivalent to DDIM in the continuum limit (Proposition 1), but takes
    Euler steps w.r.t. dt (via the 1/2 d(sigma^2) form) rather than d sigma —
    the paper notes this degrades at small S, which our benchmark confirms.
    (Not a plan backend: it discretizes a different form on purpose.)
    """
    tau = make_tau(schedule.T, S, tau_kind)
    t_cur = jnp.asarray(tau[::-1].copy(), dtype=jnp.int32)
    t_prev = jnp.asarray(np.concatenate([[0], tau[:-1]])[::-1].copy(),
                         dtype=jnp.int32)
    batch = x_T.shape[0]

    def body(x, ts):
        tc, tp = ts
        a_t, a_s = schedule.alpha_bar[tc], schedule.alpha_bar[tp]
        eps = eps_fn(x, jnp.full((batch,), tc, dtype=jnp.int32))
        xbar = x / jnp.sqrt(a_t)
        delta = 0.5 * ((1.0 - a_s) / a_s - (1.0 - a_t) / a_t)
        xbar = xbar + delta * jnp.sqrt(a_t / (1.0 - a_t)) * eps
        return xbar * jnp.sqrt(a_s), None

    x0, _ = jax.lax.scan(body, x_T, (t_cur, t_prev))
    return x0


def multistep_sample(schedule: NoiseSchedule, eps_fn: EpsFn,
                     x_T: jnp.ndarray, S: int = 25, order: int = 2,
                     tau_kind: str = "linear") -> jnp.ndarray:
    """DEPRECATED: use ``SamplerPlan.build(schedule, tau=S, order=order)``.

    Adams–Bashforth multistep DDIM (beyond-paper; paper Discussion §7):
    in x_bar/sigma coordinates the RHS is just eps, so AB-k reuses the
    last k eps evaluations — same model-eval count as DDIM but O(h^k)
    local error. Now a solver-order-k plan; kept as a thin shim.
    """
    warnings.warn(
        "multistep_sample is deprecated: use repro.sampling.SamplerPlan."
        "build(schedule, tau=S, order=order).run(eps_fn, x_T)",
        DeprecationWarning, stacklevel=2)
    return _plan(schedule, S, tau_kind, order=order).run(eps_fn, x_T)

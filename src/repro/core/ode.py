"""ODE view of DDIM (paper §4.3) — encoding, probability-flow Euler, and
multistep solvers.

With x_bar = x/sqrt(a) and sigma = sqrt((1-a)/a), DDIM is Euler on
``d x_bar = eps_theta(x) d sigma`` (Eq. 14). Integrating forward in t encodes
x0 -> x_T (a latent the deterministic sampler reconstructs from — Table 2);
the paper's Discussion suggests multistep methods (Adams–Bashforth), which we
implement here beyond the paper's own experiments.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .diffusion import EpsFn
from .schedules import NoiseSchedule, make_tau


def _sig(schedule: NoiseSchedule, t: jnp.ndarray) -> jnp.ndarray:
    a = schedule.alpha_bar[t]
    return jnp.sqrt((1.0 - a) / a)


def encode(schedule: NoiseSchedule, eps_fn: EpsFn, x0: jnp.ndarray,
           S: int = 100, tau_kind: str = "linear") -> jnp.ndarray:
    """Run Eq. 13 forward in t: x0 -> x_T (deterministic latent).

    The reverse of DDIM sampling with the same trajectory tau; Euler steps in
    sigma with eps evaluated at the left (lower-noise) endpoint.
    """
    tau = make_tau(schedule.T, S, tau_kind)
    t_from = jnp.asarray(np.concatenate([[0], tau[:-1]]), dtype=jnp.int32)
    t_to = jnp.asarray(tau, dtype=jnp.int32)
    batch = x0.shape[0]

    def body(x, ts):
        tf, tt = ts
        a_f, a_t = schedule.alpha_bar[tf], schedule.alpha_bar[tt]
        # eps is evaluated at max(tf, 1): the model grid starts at t=1.
        t_eval = jnp.full((batch,), jnp.maximum(tf, 1), dtype=jnp.int32)
        eps = eps_fn(x, t_eval)
        xbar = x / jnp.sqrt(a_f)
        xbar = xbar + (_sig(schedule, tt) - _sig(schedule, tf)) * eps
        return xbar * jnp.sqrt(a_t), None

    x_T, _ = jax.lax.scan(body, x0, (t_from, t_to))
    return x_T


def decode(schedule: NoiseSchedule, eps_fn: EpsFn, x_T: jnp.ndarray,
           S: int = 100, tau_kind: str = "linear") -> jnp.ndarray:
    """Deterministic reconstruction — DDIM sampling (kept here for symmetry
    with :func:`encode`; identical to sampler.ddim_sample)."""
    from .sampler import ddim_sample
    return ddim_sample(schedule, eps_fn, x_T, S=S, tau_kind=tau_kind)


def probability_flow_sample(schedule: NoiseSchedule, eps_fn: EpsFn,
                            x_T: jnp.ndarray, S: int = 50,
                            tau_kind: str = "linear") -> jnp.ndarray:
    """Euler discretization of the probability-flow ODE (paper Eq. 15).

    Equivalent to DDIM in the continuum limit (Proposition 1), but takes
    Euler steps w.r.t. dt (via the 1/2 d(sigma^2) form) rather than d sigma —
    the paper notes this degrades at small S, which our benchmark confirms.
    """
    tau = make_tau(schedule.T, S, tau_kind)
    t_cur = jnp.asarray(tau[::-1].copy(), dtype=jnp.int32)
    t_prev = jnp.asarray(np.concatenate([[0], tau[:-1]])[::-1].copy(),
                         dtype=jnp.int32)
    batch = x_T.shape[0]

    def body(x, ts):
        tc, tp = ts
        a_t, a_s = schedule.alpha_bar[tc], schedule.alpha_bar[tp]
        eps = eps_fn(x, jnp.full((batch,), tc, dtype=jnp.int32))
        xbar = x / jnp.sqrt(a_t)
        delta = 0.5 * ((1.0 - a_s) / a_s - (1.0 - a_t) / a_t)
        xbar = xbar + delta * jnp.sqrt(a_t / (1.0 - a_t)) * eps
        return xbar * jnp.sqrt(a_s), None

    x0, _ = jax.lax.scan(body, x_T, (t_cur, t_prev))
    return x0


def multistep_sample(schedule: NoiseSchedule, eps_fn: EpsFn,
                     x_T: jnp.ndarray, S: int = 25, order: int = 2,
                     tau_kind: str = "linear") -> jnp.ndarray:
    """Adams–Bashforth multistep DDIM (beyond-paper; paper Discussion §7).

    In x_bar/sigma coordinates the RHS is just eps, so AB-k reuses the last k
    eps evaluations: same model-eval count as DDIM but O(h^k) local error,
    improving quality at very small S.
    """
    if order not in (1, 2, 3, 4):
        raise ValueError("order must be in 1..4")
    # AB-k coefficients, padded to `order` so every branch has equal shape.
    all_coefs = [[1.0], [1.5, -0.5], [23 / 12, -16 / 12, 5 / 12],
                 [55 / 24, -59 / 24, 37 / 24, -9 / 24]]
    ab_coefs = [c + [0.0] * (order - len(c)) for c in all_coefs[:order]]
    tau = make_tau(schedule.T, S, tau_kind)
    t_cur = jnp.asarray(tau[::-1].copy(), dtype=jnp.int32)
    t_prev = jnp.asarray(np.concatenate([[0], tau[:-1]])[::-1].copy(),
                         dtype=jnp.int32)
    batch = x_T.shape[0]

    def body(carry, ts):
        x, hist, n_valid = carry            # hist: (order, *x.shape)
        tc, tp = ts
        a_t, a_s = schedule.alpha_bar[tc], schedule.alpha_bar[tp]
        eps = eps_fn(x, jnp.full((batch,), tc, dtype=jnp.int32))
        hist = jnp.concatenate([eps[None], hist[:-1]], axis=0)
        n_valid = jnp.minimum(n_valid + 1, order)
        # effective order limited by available history (Euler warm-up)
        eff = jax.lax.switch(
            n_valid - 1,
            [lambda h=h: sum(c * hist[j]
                             for j, c in enumerate(ab_coefs[h]))
             for h in range(order)])
        dsig = _sig(schedule, tp) - _sig(schedule, tc)
        xbar = x / jnp.sqrt(a_t) + dsig * eff
        return (xbar * jnp.sqrt(a_s), hist, n_valid), None

    hist0 = jnp.zeros((order,) + x_T.shape, dtype=x_T.dtype)
    (x0, _, _), _ = jax.lax.scan(
        body, (x_T, hist0, jnp.asarray(0, jnp.int32)), (t_cur, t_prev))
    return x0

"""Noise schedules for diffusion processes.

Notation follows the DDIM paper (Song et al., ICLR 2021): ``alpha_bar[t]`` is
the *cumulative* product (the paper's alpha_t, which is Ho et al.'s
``\\bar{alpha}_t`` — see paper Appendix C.2). We store ``alpha_bar`` on a grid
of T+1 points with the convention ``alpha_bar[0] == 1`` (the paper defines
``alpha_0 := 1`` below Eq. 12).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp
import numpy as np

ScheduleKind = Literal["linear", "cosine", "scaled_linear"]


@dataclasses.dataclass(frozen=True)
class NoiseSchedule:
    """Immutable container for a discrete noise schedule.

    Attributes:
      alpha_bar: (T+1,) float array, alpha_bar[0] = 1, decreasing in t.
      T: number of diffusion steps.
      kind: schedule family used to construct it.
    """

    alpha_bar: jnp.ndarray
    T: int
    kind: str

    @property
    def betas(self) -> jnp.ndarray:
        """Per-step beta_t = 1 - alpha_bar[t]/alpha_bar[t-1], shape (T,)."""
        return 1.0 - self.alpha_bar[1:] / self.alpha_bar[:-1]

    def sqrt_alpha_bar(self, t: jnp.ndarray) -> jnp.ndarray:
        return jnp.sqrt(self.alpha_bar[t])

    def sqrt_one_minus_alpha_bar(self, t: jnp.ndarray) -> jnp.ndarray:
        return jnp.sqrt(1.0 - self.alpha_bar[t])

    def snr(self, t: jnp.ndarray) -> jnp.ndarray:
        """Signal-to-noise ratio alpha_bar / (1 - alpha_bar)."""
        a = self.alpha_bar[t]
        return a / (1.0 - a)

    def sigma_continuous(self, t: jnp.ndarray) -> jnp.ndarray:
        """The ODE reparameterization sigma(t) = sqrt((1-a)/a) (paper Eq. 38)."""
        a = self.alpha_bar[t]
        return jnp.sqrt((1.0 - a) / a)


def make_schedule(kind: ScheduleKind = "linear", T: int = 1000,
                  beta_start: float = 1e-4, beta_end: float = 2e-2,
                  dtype=jnp.float32) -> NoiseSchedule:
    """Build a NoiseSchedule.

    ``linear`` is the Ho et al. (2020) heuristic the paper uses for all
    datasets (beta linear from 1e-4 to 2e-2 over T steps). ``cosine``
    (Nichol & Dhariwal) and ``scaled_linear`` are provided beyond-paper.
    """
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    if kind == "linear":
        betas = np.linspace(beta_start, beta_end, T, dtype=np.float64)
    elif kind == "scaled_linear":
        betas = np.linspace(beta_start ** 0.5, beta_end ** 0.5, T,
                            dtype=np.float64) ** 2
    elif kind == "cosine":
        s = 0.008
        steps = np.arange(T + 1, dtype=np.float64) / T
        f = np.cos((steps + s) / (1 + s) * np.pi / 2) ** 2
        ab = f / f[0]
        betas = np.clip(1.0 - ab[1:] / ab[:-1], 0.0, 0.999)
    else:
        raise ValueError(f"unknown schedule kind: {kind}")
    alpha_bar = np.concatenate([[1.0], np.cumprod(1.0 - betas)])
    return NoiseSchedule(alpha_bar=jnp.asarray(alpha_bar, dtype=dtype),
                         T=T, kind=kind)


def make_tau(T: int, S: int, kind: Literal["linear", "quadratic"] = "linear",
             ) -> np.ndarray:
    """Sampling sub-sequence tau (paper §4.2 / Appendix D.2).

    Returns an increasing array of S timesteps in [1, T].
      linear:    tau_i = floor(c * i)
      quadratic: tau_i = floor(c * i^2)   (used for CIFAR10 in the paper)
    with c chosen so tau_{-1} is close to T.
    """
    if not 1 <= S <= T:
        raise ValueError(f"need 1 <= S <= T, got S={S} T={T}")
    i = np.arange(1, S + 1, dtype=np.float64)
    if kind == "linear":
        c = T / S
        tau = np.floor(c * i)
    elif kind == "quadratic":
        c = T / (S ** 2)
        tau = np.floor(c * i * i)
    else:
        raise ValueError(f"unknown tau kind: {kind}")
    tau = np.unique(np.clip(tau.astype(np.int64), 1, T))
    # de-duplication may shorten the trajectory for extreme (S, kind) combos;
    # pad from the missing low timesteps to preserve length S.
    if len(tau) < S:
        missing = np.setdiff1d(np.arange(1, T + 1), tau)
        tau = np.sort(np.concatenate([tau, missing[: S - len(tau)]]))
    return tau

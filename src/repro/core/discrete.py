"""Non-Markovian multinomial forward process for discrete data (paper App. A).

The paper defines it and leaves experiments to future work; we implement the
full process + a trainable reverse model interface so the toy experiment in
examples/discrete_ddim.py can exercise it.

For one-hot x0 with K classes:
  q(x_t | x0)            = Cat(a_t x0 + (1 - a_t) 1/K)                 (Eq. 17)
  q(x_{t-1} | x_t, x0)   = Cat(s_t x_t + (a_{t-1} - s_t a_t) x0
                               + ((1-a_{t-1}) - (1-a_t) s_t) 1/K)      (Eq. 19)
  p_theta(x_{t-1} | x_t) = same with x0 -> f_theta(x_t)                (Eq. 20)

s_t (the paper's sigma_t) controls stochasticity: choosing s_t so that the
uniform-mass term vanishes gives the "implicit" (DDIM-like) limit where the
chain either keeps x_t or jumps to the predicted x0.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .schedules import NoiseSchedule

# f_theta(x_t, t) -> (batch, ..., K) probabilities of x0
X0Fn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _b(coef: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    return coef.reshape(coef.shape + (1,) * (x.ndim - coef.ndim))


def q_probs(schedule: NoiseSchedule, x0: jnp.ndarray,
            t: jnp.ndarray) -> jnp.ndarray:
    """Marginal Cat probabilities of x_t given one-hot x0 (Eq. 17)."""
    K = x0.shape[-1]
    a = schedule.alpha_bar[t]
    return _b(a, x0) * x0 + _b(1.0 - a, x0) / K


def q_sample(schedule: NoiseSchedule, x0: jnp.ndarray, t: jnp.ndarray,
             rng: jax.Array) -> jnp.ndarray:
    """Draw one-hot x_t ~ q(x_t | x0)."""
    p = q_probs(schedule, x0, t)
    idx = jax.random.categorical(rng, jnp.log(p + 1e-20), axis=-1)
    return jax.nn.one_hot(idx, x0.shape[-1], dtype=x0.dtype)


def sigma_implicit(schedule: NoiseSchedule, t: jnp.ndarray,
                   s: jnp.ndarray) -> jnp.ndarray:
    """The s_t that zeroes the uniform-mass term: (1-a_s)/(1-a_t).

    This is the discrete analogue of eta=0 — maximally deterministic while
    keeping all mixture weights in Eq. 18 non-negative.
    """
    return (1.0 - schedule.alpha_bar[s]) / (1.0 - schedule.alpha_bar[t])


def posterior_probs(schedule: NoiseSchedule, x_t: jnp.ndarray,
                    x0: jnp.ndarray, t: jnp.ndarray, s: jnp.ndarray,
                    sigma: jnp.ndarray) -> jnp.ndarray:
    """q(x_s | x_t, x0) mixture probabilities (Eq. 19), generalized t->s."""
    K = x_t.shape[-1]
    a_t = schedule.alpha_bar[t]
    a_s = schedule.alpha_bar[s]
    w_t = sigma
    w_0 = a_s - sigma * a_t
    w_u = (1.0 - a_s) - (1.0 - a_t) * sigma
    return (_b(w_t, x_t) * x_t + _b(w_0, x_t) * x0 +
            _b(w_u, x_t) / K)


def reverse_sample(schedule: NoiseSchedule, x0_fn: X0Fn, x_T: jnp.ndarray,
                   rng: jax.Array, S: int, eta: float = 0.0,
                   tau_kind: str = "linear") -> jnp.ndarray:
    """Sample the reverse multinomial chain on a sub-sequence tau.

    eta interpolates sigma between 0 (fully stochastic jump to uniform terms)
    and the implicit value (deterministic keep-or-jump): sigma = eta * sigma*.
    """
    from .schedules import make_tau
    import numpy as np
    tau = make_tau(schedule.T, S, tau_kind)
    t_cur = jnp.asarray(tau[::-1].copy(), dtype=jnp.int32)
    t_prev = jnp.asarray(np.concatenate([[0], tau[:-1]])[::-1].copy(),
                         dtype=jnp.int32)
    batch = x_T.shape[0]

    def body(carry, per):
        x, key = carry
        tc, tp = per
        key, k1 = jax.random.split(key)
        probs_x0 = x0_fn(x, jnp.full((batch,), tc, dtype=jnp.int32))
        sig = eta * sigma_implicit(schedule, tc, tp)
        p = posterior_probs(schedule, x, probs_x0, tc, tp, sig)
        idx = jax.random.categorical(k1, jnp.log(p + 1e-20), axis=-1)
        x_new = jax.nn.one_hot(idx, x.shape[-1], dtype=x.dtype)
        return (x_new, key), None

    (x0, _), _ = jax.lax.scan(body, (x_T, rng), (t_cur, t_prev))
    return x0


def kl_loss(schedule: NoiseSchedule, x0_fn: X0Fn, x0: jnp.ndarray,
            t: jnp.ndarray, rng: jax.Array, eta: float = 0.9) -> jnp.ndarray:
    """Variational KL between the true and model posteriors (Eq. 21).

    Bounded above by a weighted classification loss (App. A last eq.) — we
    optimize the exact categorical KL, which is tractable.
    """
    x_t = q_sample(schedule, x0, t, rng)
    s = jnp.maximum(t - 1, 0)
    sig = eta * sigma_implicit(schedule, t, s)
    q_p = posterior_probs(schedule, x_t, x0, t, s, sig)
    p_p = posterior_probs(schedule, x_t, x0_fn(x_t, t), t, s, sig)
    kl = jnp.sum(q_p * (jnp.log(q_p + 1e-20) - jnp.log(p_p + 1e-20)), axis=-1)
    return jnp.mean(kl)

"""Adams–Bashforth multistep machinery shared by every sampler surface.

The plan compiler (repro.sampling.plan), the three plan backends
(repro.sampling.backends) and the continuous-batching scheduler tick
(core/sampler.slot_tile_step) all consume these THREE primitives — the
coefficient table, the warm-up weight matrix, and the history combine.
There is deliberately exactly ONE combine implementation: the scheduler's
"replays plan.run(backend='rows') bit-for-bit" guarantee rests on it.

This module sits at the bottom of the dependency graph (numpy/jnp only),
so both `repro.core` and `repro.sampling` import it downward — no
package cycle, no private cross-package reach.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Adams–Bashforth weights by effective order (paper Discussion §7 /
# Liu et al.'s PLMS use the same table); row h = the order-(h+1) method.
AB_COEFS = (
    (1.0,),
    (1.5, -0.5),
    (23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0),
    (55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0),
)
MAX_ORDER = len(AB_COEFS)


def warmup_weights(S: int, order: int) -> np.ndarray:
    """(S, order) AB weights with Euler warm-up baked in.

    Step k (execution order, either integration direction) uses at most
    k+1 history entries, so no consumer branches at runtime — a freshly
    admitted scheduler slot reads a predecessor's stale history only
    through columns this matrix zeroes.
    """
    w = np.zeros((S, order), np.float64)
    for k in range(S):
        row = AB_COEFS[min(k + 1, order) - 1]
        w[k, :len(row)] = row
    return w


def mix_history(eps32, hist, w, order: int):
    """The AB combine: (effective eps, updated history).

    ``w[j]`` is the step's j-th weight — warm-up zeros included — and may
    be a scalar (the lockstep backends) or a (rows, 1) column (the
    scheduler tick passes an (order, rows, 1) stack so every slot applies
    its own weight row); either broadcasts over ``eps32``/``hist``
    entries.  History holds the PREVIOUS order-1 eps evaluations, newest
    first, in float32.
    """
    if order == 1:
        return eps32, hist
    eff = w[0] * eps32
    for j in range(1, order):
        eff = eff + w[j] * hist[j - 1]
    new_hist = (jnp.concatenate([eps32[None], hist[:-1]], axis=0)
                if order > 2 else eps32[None])
    return eff, new_hist

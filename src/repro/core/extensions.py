"""Beyond-paper sampler extensions (post-2021 standard practice), built on
the same schedule/marginal machinery:

* v-prediction (Salimans & Ho 2022): the network predicts
  v = sqrt(a) eps - sqrt(1-a) x0. Better-conditioned at high noise; we
  provide exact adapters so a v-model plugs into the paper's Eq. 12 sampler
  unchanged (everything reduces to an eps_fn).
* classifier-free guidance (Ho & Salimans 2021): eps_cfg = eps_u +
  w (eps_c - eps_u), again exposed as an eps_fn so all samplers (DDIM,
  DDPM, AB-multistep, PF-Euler) inherit guidance for free — this
  composability is a direct payoff of the paper's "everything is an
  eps-model over fixed marginals" framing.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from .diffusion import EpsFn, _bcast
from .schedules import NoiseSchedule


def v_from_eps_x0(schedule: NoiseSchedule, t, eps, x0):
    a = schedule.alpha_bar[t]
    return (_bcast(jnp.sqrt(a), eps) * eps
            - _bcast(jnp.sqrt(1.0 - a), eps) * x0)


def eps_from_v(schedule: NoiseSchedule, x_t, t, v):
    """Invert v-parameterization: eps = sqrt(a) v + sqrt(1-a) x_t."""
    a = schedule.alpha_bar[t]
    return (_bcast(jnp.sqrt(a), v) * v
            + _bcast(jnp.sqrt(1.0 - a), v) * x_t)


def x0_from_v(schedule: NoiseSchedule, x_t, t, v):
    a = schedule.alpha_bar[t]
    return (_bcast(jnp.sqrt(a), v) * x_t
            - _bcast(jnp.sqrt(1.0 - a), v) * v)


def eps_fn_from_v_fn(schedule: NoiseSchedule, v_fn: Callable) -> EpsFn:
    """Wrap a v-predictor as an eps_fn for the Eq. 12 sampler family."""
    def eps_fn(x_t, t):
        return eps_from_v(schedule, x_t, t, v_fn(x_t, t))
    return eps_fn


def v_training_target(schedule: NoiseSchedule, x0, t, noise):
    """The regression target for v-models (same q_sample inputs as L_1)."""
    return v_from_eps_x0(schedule, t, noise, x0)


def cfg_eps_fn(eps_cond: EpsFn, eps_uncond: EpsFn,
               guidance: float) -> EpsFn:
    """Classifier-free guidance over any pair of eps models."""
    def eps_fn(x_t, t):
        eu = eps_uncond(x_t, t)
        ec = eps_cond(x_t, t)
        return eu + guidance * (ec - eu)
    return eps_fn
